"""Paper Fig 2 analogue: PHOLD throughput vs lookahead L and event population M
(fixed model size; CPU-scaled O/S, same parameter axes as the paper)."""
from __future__ import annotations

from .common import build, throughput


def run(rows):
    for m in (10, 100):
        for la in (0.1, 0.5, 1.0):
            eng = build(o=256, m=m, s=256, lookahead=la, dist="exponential",
                        bucket_cap=max(64, 4 * m))
            ev_s, n, dt, clean = throughput(eng, warmup_epochs=5, epochs=30)
            rows.append({
                "name": f"fig2_speed_L{la}_M{m}",
                "us_per_call": 1e6 * dt / max(n, 1),
                "derived": f"events_per_s={ev_s:.0f} n={n} clean={clean}",
            })
    return rows
