"""Paper Fig 3 analogue: strong scaling — fixed model, growing worker count.

Host devices stand in for CPUs/chips (subprocess per device count since JAX
locks the device count at first init)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_CHILD = textwrap.dedent("""
    import json, sys
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core.engine import AXIS
    sys.path.insert(0, "benchmarks")
    from common import build, throughput

    n = int(sys.argv[1])
    mesh = Mesh(np.array(jax.devices()[:n]), (AXIS,))
    eng = build(o=512, m=20, s=256, lookahead=0.5, dist="exponential",
                mesh=mesh)
    ev_s, nev, dt, clean = throughput(eng, warmup_epochs=5, epochs=25)
    print(json.dumps({"ev_s": ev_s, "n": nev, "dt": dt, "clean": clean}))
""")


def run(rows):
    for n in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = "src"
        r = subprocess.run([sys.executable, "-c", _CHILD, str(n)], env=env,
                           capture_output=True, text=True, timeout=1200)
        if r.returncode != 0:
            rows.append({"name": f"fig3_scaling_W{n}", "us_per_call": -1,
                         "derived": f"error={r.stderr[-200:]}"})
            continue
        d = json.loads(r.stdout.strip().splitlines()[-1])
        rows.append({
            "name": f"fig3_scaling_W{n}",
            "us_per_call": 1e6 * d["dt"] / max(d["n"], 1),
            "derived": f"events_per_s={d['ev_s']:.0f} clean={d['clean']}",
        })
    return rows
