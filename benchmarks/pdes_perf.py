"""§Perf for the paper's own engine: hypothesis→change→measure iterations.

Runs the PARSIR engine hillclimb ladder on CPU (wall-clock events/s) and
reports, for each routing strategy, the *structural* per-epoch exchange bytes
(what the ICI would carry on a pod) — the measurable CPU proxy plus the
analytic collective term.

``--workload`` selects any registered zoo workload (repro/workloads), so the
perf trajectory covers skewed traffic (phold-hotspot), FIFO-coupled traffic
(queueing) and deterministic ring traffic (cluster), not just uniform PHOLD.

The ``it4_fused_drain`` rung measures *dispatches-per-simulation* — the same
window driven one-host-dispatch-per-epoch, in fixed fused chunks, and as one
``lax.while_loop`` dispatch (``run_until_drained``; must report exactly 1).
The ``it5_campaign`` rung (wireless) measures *dispatches-per-campaign*:
32 replication seeds of the draining simulation run one-fused-drain-per-seed
vs all 32 stacked through the replication-vmapped while_loop (must report
exactly 1 dispatch for the whole sweep).  When the seed count divides the
device count the stacked drive runs replication-*sharded* (``rep_shards``:
each replication collective-free on its own device, capacities right-sized
to one replication's traffic via ``rep_engine_kw``) — the layout that wins
at campaign scale.
The ``it6_speculation`` rung (wireless + epidemic, the draining loads)
sweeps ``opt_window`` over {0, 1, 2, 4} and measures *epochs-to-drain* —
fused while-loop iterations, ``(spec_commits + rollbacks) / D`` when
speculating (the meters count once per device per window) — which must
fall strictly below the conservative drain at every W while the drained
bits stay identical; rollbacks are reported alongside.
The ``it7_per_device_commit`` rung (same draining loads) drives a fixed
window once under the PR 9 global all-or-nothing vote and once under
per-device commit, and measures *rolled-back device-windows* — the
``rollbacks`` counter, one per device per aborted window — which the
per-device verdict must strictly reduce (or drain in strictly fewer
iterations) while reaching bit-identical drained state.
Any rung whose run is unclean (nonzero overflow/causality counter, the full
:mod:`repro.testing.clean` set) fails the driver with a nonzero exit —
a perf number from a run that dropped events is not a result.  Draining
rungs (``expect_drained``) additionally fail if they hit their epoch bound
with events still in flight: ev/s from a simulation that never finished is
not a result either.

  PYTHONPATH=src python -m benchmarks.pdes_perf [--devices 8]
  PYTHONPATH=src python -m benchmarks.pdes_perf --workload phold-hotspot
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

_CHILD = textwrap.dedent("""
    import json, sys, time
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core.engine import AXIS, EngineConfig, ParsirEngine
    from repro.workloads.registry import get_workload

    spec = json.loads(sys.argv[1])
    D = spec["devices"]
    mesh = Mesh(np.array(jax.devices()[:D]), (AXIS,))
    wname = spec.get("workload", "phold")
    model_kw = dict(n_objects=spec["o"], lookahead=spec["la"],
                    dist=spec["dist"], **spec.get("model_kw", {}))
    if wname in ("phold", "phold-hotspot"):
        model_kw.update(initial_events=spec["m"], state_nodes=spec["s"],
                        realloc_fraction=0.004)
        # hot_o/hot_p ladder overrides apply to BOTH phold workloads (the
        # hotspot ladder used to silently run with default hot params).
        if "hot_o" in spec:
            model_kw["hot_objects"] = spec["hot_o"]
        if "hot_p" in spec:
            model_kw["hot_prob"] = spec["hot_p"]
    try:
        model = get_workload(wname, **model_kw)
    except TypeError as e:
        # unknown model_kw keys must fail fast and loudly, never be dropped.
        # Anything other than a bad-kwarg TypeError is a real bug: keep its
        # traceback instead of mislabeling it as a spec problem.
        if "unexpected keyword argument" not in str(e):
            raise
        raise SystemExit(f"bad model_kw for workload {wname!r}: {e} "
                         f"(keys: {sorted(model_kw)})")
    ckw = dict(lookahead=spec["la"],
               epoch_len=spec.get("epoch_len"),
               n_buckets=32, bucket_cap=spec.get("bucket_cap", 256),
               route_cap=spec["route_cap"], fallback_cap=16384,
               route=spec["route"], scheduler=spec.get("sched", "batch"),
               steal=spec.get("steal", False), steal_cap=8,
               claim_cap=16,
               batch_impl=spec.get("batch_impl", "rounds"),
               pack_tile=spec.get("pack_tile", 64),
               placement=spec.get("placement", "equal"),
               rebalance_every=spec.get("rebalance_every", 0),
               migrate_cap=spec.get("migrate_cap", 16),
               placement_slack=spec.get("placement_slack", 2.0),
               opt_window=spec.get("opt_window", 0),
               opt_stage_cap=spec.get("opt_stage_cap", 0),
               opt_commit=spec.get("opt_commit", "device"))
    cfg = EngineConfig(**ckw)
    eng = ParsirEngine(model, cfg, mesh=mesh)
    from repro.testing import unclean_counters

    if spec.get("speculation"):
        # speculation rung (PR 9): the SAME draining simulation driven by the
        # fused while_loop at every opt_window W in spec["windows"].  The
        # honest metric is *epochs-to-drain* — while-loop iterations, i.e.
        # spec_commits + rollbacks when speculating, epochs_run at W=0 —
        # because each iteration is one barrier'd dispatch round: the window
        # must cut iterations strictly below the conservative drain while
        # reaching bit-identical drained state (asserted below, every W
        # against the W=0 bits).  Rollbacks are *expected* at D>1 (every
        # cross-device event into an open window is a straggler) and the
        # rung surfaces them next to the win they price.
        E = spec["epochs"]
        windows, base, failures = {}, None, []
        for W in spec["windows"]:
            eng_w = ParsirEngine(model, EngineConfig(**dict(
                ckw, opt_window=W)), mesh=mesh)
            jax.block_until_ready(eng_w.run_until_drained(eng_w.init(), E))
            st = eng_w.init()                       # measured pass
            t0 = time.perf_counter()
            st = eng_w.run_until_drained(st, E)
            jax.block_until_ready(st)
            dt = time.perf_counter() - t0
            tot = eng_w.totals(st)
            epochs_run = int(np.asarray(st.epoch)[0])
            # the commit/rollback meters tick once per device per window
            # (so their per-device sums equal the fused-loop iteration
            # count on every device) — normalize totals back to windows.
            iters = ((tot["spec_commits"] + tot["rollbacks"]) // D if W
                     else epochs_run)
            obj = {k: np.asarray(v) for k, v in
                   eng_w.global_object_state(st).items()}
            if base is None:
                base = dict(iters=iters, n=tot["processed"], obj=obj)
            else:
                assert tot["processed"] == base["n"], \
                    f"W={W} diverged: {tot['processed']} != {base['n']}"
                for k in obj:
                    assert np.array_equal(obj[k], base["obj"][k]), \
                        f"W={W} object state {k!r} diverges from W=0"
                if iters >= base["iters"]:
                    failures.append(f"W={W}: {iters} iterations >= "
                                    f"conservative {base['iters']}")
            windows[f"w{W}"] = {
                "opt_window": W, "epochs_to_drain": iters,
                "epochs_run": epochs_run, "dt": dt,
                "ev_s": tot["processed"] / dt,
                "rollbacks": tot["rollbacks"],
                "spec_commits": tot["spec_commits"],
                "speculated": tot["speculated"],
                "drained": eng_w.in_flight(st) == 0,
                "unclean": unclean_counters(tot)}
        assert not failures, f"speculation never won: {failures}"
        bad = {}
        for wrec in windows.values():
            for k, v in wrec["unclean"].items():
                bad[k] = bad.get(k, 0) + v
        drained = all(wrec["drained"] for wrec in windows.values())
        best = max(windows.values(), key=lambda wrec: wrec["ev_s"])
        print(json.dumps({"ev_s": best["ev_s"], "n": base["n"],
                          "windows": windows, "unclean": bad,
                          "drained": drained, "bound_hit": not drained,
                          "epochs_run": max(wrec["epochs_run"]
                                            for wrec in windows.values())}))
        raise SystemExit(0)

    if spec.get("commit_compare"):
        # per-device-commit rung (PR 10): the SAME draining simulation at a
        # fixed opt_window, driven once under the PR 9 global all-or-nothing
        # vote and once under per-device commit.  The honest waste metric is
        # *rolled-back device-windows* — the rollbacks counter ticks once per
        # device per aborted window, so under the global vote one straggler
        # anywhere prices D device-windows of discarded work while the
        # per-device verdict aborts only the devices a straggler actually
        # reached.  The verdict must strictly reduce that waste (or, because
        # committed-early emissions shift later arrival timing, drain in
        # strictly fewer fused-loop iterations) while the drained object
        # state stays bit-identical between the two commit modes.
        E, W = spec["epochs"], spec["opt_window"]
        recs, base = {}, None
        for mode in ("global", "device"):
            eng_m = ParsirEngine(model, EngineConfig(**dict(
                ckw, opt_window=W, opt_commit=mode)), mesh=mesh)
            jax.block_until_ready(eng_m.run_until_drained(eng_m.init(), E))
            st = eng_m.init()                       # measured pass
            t0 = time.perf_counter()
            st = eng_m.run_until_drained(st, E)
            jax.block_until_ready(st)
            dt = time.perf_counter() - t0
            tot = eng_m.totals(st)
            iters = (tot["spec_commits"] + tot["rollbacks"]) // D
            obj = {k: np.asarray(v) for k, v in
                   eng_m.global_object_state(st).items()}
            if base is None:
                base = dict(n=tot["processed"], obj=obj)
            else:
                assert tot["processed"] == base["n"], \
                    f"{mode} diverged: {tot['processed']} != {base['n']}"
                for k in obj:
                    assert np.array_equal(obj[k], base["obj"][k]), \
                        f"{mode} object state {k!r} diverges from global vote"
            recs[mode] = {
                "opt_commit": mode, "opt_window": W,
                "epochs_to_drain": iters,
                "epochs_run": int(np.asarray(st.epoch)[0]), "dt": dt,
                "ev_s": tot["processed"] / dt,
                "rolled_back_device_windows": tot["rollbacks"],
                "committed_device_windows": tot["spec_commits"],
                "speculated": tot["speculated"],
                "drained": eng_m.in_flight(st) == 0,
                "unclean": unclean_counters(tot)}
        g, d = recs["global"], recs["device"]
        # the strict win is only claimable when the global vote actually
        # rolled work back (a straggler-free smoke drain has no waste for
        # the per-device verdict to reduce).
        if g["rolled_back_device_windows"]:
            assert (d["rolled_back_device_windows"]
                    < g["rolled_back_device_windows"]) or \
                   (d["epochs_to_drain"] < g["epochs_to_drain"]), \
                (f"per-device commit never won: rolled back "
                 f"{d['rolled_back_device_windows']} device-windows vs "
                 f"global {g['rolled_back_device_windows']}, drained in "
                 f"{d['epochs_to_drain']} iters vs {g['epochs_to_drain']}")
        bad = {}
        for rec in recs.values():
            for k, v in rec["unclean"].items():
                bad[k] = bad.get(k, 0) + v
        drained = all(rec["drained"] for rec in recs.values())
        print(json.dumps({"ev_s": d["ev_s"], "n": base["n"],
                          "modes": recs, "unclean": bad,
                          "rollback_reduction":
                              g["rolled_back_device_windows"]
                              - d["rolled_back_device_windows"],
                          "drained": drained, "bound_hit": not drained,
                          "epochs_run": max(rec["epochs_run"]
                                            for rec in recs.values())}))
        raise SystemExit(0)

    if spec.get("campaign"):
        # campaign rung: R replication seeds of the SAME draining simulation,
        # driven (a) one fused drain per seed (the PR6 state of the art) and
        # (b) all R stacked through ONE replication-vmapped while_loop
        # (run_replicated_drained).  dispatches-per-campaign is the honest
        # metric — the vmapped drive must hit exactly 1 — and per-seed
        # processed totals must agree across drives (each replication is
        # leaf-exact vs its own independent drain by construction).
        # Execution layout for the stacked drive: when the campaign has more
        # replications than devices, shard the REPLICATION axis instead of
        # the object axis (rep_shards=D on a single-device engine mesh) —
        # each replication runs collective-free on its own device, which
        # beats D-way object sharding whenever one replication fits a
        # device (the a2a/allgather sync per epoch costs more than the
        # whole single-device step at these object counts).
        # The rep-sharded engine also right-sizes its static capacities to
        # ONE replication's traffic (spec key rep_engine_kw; the ladder's
        # caps are sized for 4-way object-sharded device traffic and their
        # slack is pure per-epoch fixed cost — the extract sort alone walks
        # bucket_cap slots per object per epoch).  Any under-sizing trips
        # the overflow counters and fails the rung, and the per-seed
        # processed-equality assert below holds both drives to identical
        # event flow.
        E, R = spec["epochs"], spec["reps"]
        seeds = list(range(R))
        rep_kw = spec.get("rep_engine_kw", {})
        if D > 1 and R % D == 0:
            eng_v = ParsirEngine(model, EngineConfig(**dict(ckw, **rep_kw)),
                                 mesh=Mesh(np.array(jax.devices()[:1]),
                                           (AXIS,)),
                                 rep_shards=D)
        else:
            eng_v = eng

        def drive(mode):
            if mode == "host_loop":
                per, infl, disp, dt, bad, epochs = [], 0, 0, 0.0, {}, 0
                for s in seeds:
                    st = eng.init(seed=s)
                    d0 = eng.dispatches
                    t0 = time.perf_counter()
                    st = eng.run_until_drained(st, E)
                    jax.block_until_ready(st)
                    dt += time.perf_counter() - t0
                    disp += eng.dispatches - d0
                    tot = eng.totals(st)
                    per.append(tot["processed"])
                    infl += eng.in_flight(st)
                    epochs = max(epochs, int(np.asarray(st.epoch)[0]))
                    for k, v in unclean_counters(tot).items():
                        bad[k] = bad.get(k, 0) + v
                return per, infl, disp, dt, bad, epochs
            st = eng_v.init_replicated(seeds)
            d0 = eng_v.dispatches
            t0 = time.perf_counter()
            st = eng_v.run_replicated_drained(st, E)
            jax.block_until_ready(st)
            dt = time.perf_counter() - t0
            disp = eng_v.dispatches - d0
            totr = eng_v.totals_replicated(st)
            per = [t["processed"] for t in totr]
            infl = int(eng_v.in_flight_replicated(st).sum())
            bad = {}
            for t in totr:
                for k, v in unclean_counters(t).items():
                    bad[k] = bad.get(k, 0) + v
            epochs = int(np.asarray(st.epoch)[:, 0].max())
            return per, infl, disp, dt, bad, epochs

        modes, per_seed, unclean, infl_total = {}, {}, {}, 0
        epochs_run = 0
        for mode in ("host_loop", "vmapped"):
            drive(mode)                                   # compile pass
            per, infl, disp, dt, bad, epochs = drive(mode)
            per_seed[mode] = per
            unclean.update(bad)
            infl_total += infl
            epochs_run = max(epochs_run, epochs)
            modes[mode] = {"dispatches_per_campaign": disp, "dt": dt,
                           "ev_s": sum(per) / dt}
        assert per_seed["host_loop"] == per_seed["vmapped"], \
            f"drives diverged per seed: {per_seed}"
        assert modes["vmapped"]["dispatches_per_campaign"] == 1, modes
        drained = infl_total == 0
        print(json.dumps({"ev_s": modes["vmapped"]["ev_s"],
                          "n": sum(per_seed["vmapped"]),
                          "replications": R,
                          "rep_shards": eng_v.rep_shards,
                          "rep_engine_kw": rep_kw,
                          "per_seed": per_seed["vmapped"],
                          "speedup_vs_host_loop":
                              modes["vmapped"]["ev_s"]
                              / modes["host_loop"]["ev_s"],
                          "modes": modes, "unclean": unclean,
                          "drained": drained, "bound_hit": not drained,
                          "epochs_run": epochs_run}))
        raise SystemExit(0)

    if spec.get("fused_drain"):
        # dispatch-ladder rung: the same simulation window driven three ways
        # — one host dispatch per epoch, fixed-size fused chunks, and the
        # whole window as ONE lax.while_loop dispatch (run_until_drained).
        # dispatches-per-simulation is the honest metric on CPU, where host
        # dispatch overhead swamps compute; processed totals must agree
        # across all three (drained state is a step fixpoint).
        E, C = spec["epochs"], spec.get("chunk", 6)

        def drive(mode):
            st = eng.init()
            d0 = eng.dispatches
            t0 = time.perf_counter()
            if mode == "host_stepped":
                for _ in range(E):
                    st = eng.step(st)
            elif mode == "fixed_chunks":
                for lo in range(0, E, C):
                    st = eng.run(st, min(C, E - lo))
            else:
                st = eng.run_until_drained(st, E)
            jax.block_until_ready(st)
            return st, eng.dispatches - d0, time.perf_counter() - t0

        modes, processed = {}, {}
        for mode in ("host_stepped", "fixed_chunks", "fused_drain"):
            drive(mode)                       # compile pass
            st, disp, dt = drive(mode)        # measured pass
            tot = eng.totals(st)
            processed[mode] = tot["processed"]
            modes[mode] = {"dispatches_per_simulation": disp, "dt": dt,
                           "ev_s": tot["processed"] / dt}
        assert len(set(processed.values())) == 1, \
            f"drive modes diverged: {processed}"
        assert modes["fused_drain"]["dispatches_per_simulation"] == 1, modes
        tot["rebalances"] //= D
        drained = eng.in_flight(st) == 0
        print(json.dumps({"ev_s": modes["fused_drain"]["ev_s"],
                          "n": processed["fused_drain"], "stats": tot,
                          "unclean": unclean_counters(tot), "modes": modes,
                          "drained": drained, "bound_hit": not drained,
                          "epochs_run": int(np.asarray(st.epoch)[0])}))
        raise SystemExit(0)

    st = eng.run(eng.init(), spec.get("warm", 6))
    base = eng.totals(st)["processed"]
    # structural schedule cost of the warmed-up epoch, summed over devices:
    # the dense rounds grid executes max_depth x n_local_max lanes per device
    # whether occupied or not; packing executes ~the events present.  This is
    # the padded-row-tax proxy a wide-SIMD accelerator would feel directly —
    # CPU wall-clock mostly measures loop dispatch instead.
    occ = eng.occupancy(st)
    lanes = {"padded_lanes_epoch": int(occ["padded_lanes"].sum()),
             "packed_lanes_epoch": int(occ["packed_lanes"].sum()),
             "n_local_max": int(occ["n_local_max"])}
    t0 = time.perf_counter()
    st = eng.run(st, spec["epochs"])
    st.stats.processed.block_until_ready()
    dt = time.perf_counter() - t0
    tot = eng.totals(st)
    n = tot["processed"] - base
    # structural exchange bytes per epoch: record bytes are 17B/event
    # (dst4 ts4 seed4 payload4 valid1)
    rec_b = 17
    if spec["route"] == "allgather":
        ex = D * D * spec["route_cap"] * rec_b          # D bufs to D devices
    else:
        ex = D * spec["route_cap"] * rec_b              # pairwise a2a
    def state_bytes():
        # per-object state bytes, generic over workloads: one object's pytree.
        st0 = model.init_object_state(np.arange(1))
        return sum(np.asarray(l).nbytes for l in jax.tree.leaves(st0)) + 8
    if spec.get("steal"):
        loan_b = 8 * (cfg.bucket_cap * 12 + state_bytes())
        ex += 2 * D * D * loan_b                        # publish + return
    if spec.get("rebalance_every"):
        # migration all_gather: up to K whole rows (calendar + state) per
        # device, broadcast D-wide, amortized over the rebalance period.
        K = 2 * (cfg.migrate_cap // 2)
        row_b = (cfg.n_buckets * cfg.bucket_cap * 12 + cfg.n_buckets * 4
                 + state_bytes() + 4)
        ex += D * D * K * row_b // spec["rebalance_every"]
    # rebalances: every device reports each firing — normalize to firings so
    # the recorded counter partitions like processed/stolen/migrated do.
    tot["rebalances"] //= D
    print(json.dumps({"ev_s": n / dt, "n": n, "dt": dt, "stats": tot,
                      "unclean": unclean_counters(tot),
                      "exchange_bytes_per_epoch": ex, "lanes": lanes}))
""")

BASE = dict(o=512, m=40, s=256, la=0.5, dist="exponential", route_cap=8192,
            epochs=30)

# workload-specific bench-scale extras forwarded to make().
BENCH_MODEL_KW = {
    # at bench scale, spread the hot set so per-object batches fit bucket_cap
    # (same skew point as the uniform-phold skew ladder rows).
    "phold-hotspot": dict(hot_objects=32, hot_prob=96, hot_boost=1),
    "queueing": dict(n_jobs=2048),
    "cluster": dict(n_rings=64),
    # open network: n_objects is split ~evenly across the five roles by
    # make(); unbounded sources keep the arrival stream going all run.
    "open-queueing": dict(),
    # enough seeds/susceptibles that the epidemic is still growing (not
    # burned out) across the measured window.
    "epidemic": dict(pop=64, n_seeds=32, trans_p=128),
    # the natively hotspot-prone load (PR 5): a hot head with extra
    # generator streams on a finer arrival grid — what the placement
    # ladder below rebalances.
    "wireless": dict(n_channels=8, hot_cells=32, hot_shift=3,
                     hot_streams=2, handoff_p=112),
}


def run_child(devices: int, workload: str, **spec):
    model_kw = dict(BENCH_MODEL_KW.get(workload, {}),
                    **spec.pop("model_kw", {}))
    merged = dict(BASE, devices=devices, workload=workload,
                  model_kw=model_kw, **spec)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _CHILD, json.dumps(merged)],
                       env=env, capture_output=True, text=True, timeout=2400)
    if r.returncode != 0:
        return {"error": r.stderr[-300:]}
    return json.loads(r.stdout.strip().splitlines()[-1])


def build_ladder(workload: str):
    ladder = [
        ("baseline_paper_faithful", dict(route="allgather")),
        ("it1_route_a2a", dict(route="a2a")),
        ("it2_epoch_half_L", dict(route="a2a", epoch_len=0.25)),
        # the width-packed scheduler (PR 4): process only the occupied event
        # slots — the anti-padded-row-tax rung, same bits by construction.
        ("it3_width_packed", dict(route="a2a", batch_impl="packed")),
        # the fused on-device loop (PR 6): the same window driven host-stepped
        # / fixed-chunked / as ONE while_loop dispatch — the rung reports
        # dispatches-per-simulation per mode (the fused mode must hit 1).
        ("it4_fused_drain", dict(route="a2a", fused_drain=True)),
    ]
    if workload == "phold":
        # uniform PHOLD needs explicit hot params to produce skew.
        ladder += [
            ("skew_baseline_nosteal", dict(route="a2a", hot_o=32, hot_p=96,
                                           bucket_cap=512)),
            ("skew_it3_steal", dict(route="a2a", hot_o=32, hot_p=96,
                                    bucket_cap=512, steal=True)),
        ]
    else:
        # phold-hotspot is skewed by construction; queueing/cluster measure
        # the stealing overhead on their native (im)balance.
        ladder += [
            ("steal_off", dict(route="a2a", bucket_cap=512)),
            ("steal_on", dict(route="a2a", bucket_cap=512, steal=True)),
        ]
    if workload in ("phold-hotspot", "wireless"):
        # the placement ladder: static knapsack from the model's weight hint,
        # runtime rebalancing, and rebalancing composed with loans — measured
        # against the equal-placement `steal_off` rung above.  Each placement
        # is measured under both batch impls: the `_packed` twins quantify
        # how much of the uneven-placement loss is the padded-row tax the
        # width-packer removes (BENCH_pr3 showed weighted/adaptive losing to
        # equal exactly by that tax).  wireless (PR 5) runs the same ladder
        # on a model-native hotspot — skew from the workload's own physics
        # rather than a synthetic routing knob.
        pl = dict(route="a2a", bucket_cap=512, placement_slack=1.5)
        ladder += [
            ("packed_equal", dict(route="a2a", bucket_cap=512,
                                  batch_impl="packed")),
            ("placement_weighted", dict(pl, placement="weighted")),
            ("placement_weighted_packed",
             dict(pl, placement="weighted", batch_impl="packed")),
            ("placement_adaptive", dict(pl, placement="adaptive",
                                        rebalance_every=4, migrate_cap=64)),
            ("placement_adaptive_packed",
             dict(pl, placement="adaptive", rebalance_every=4,
                  migrate_cap=64, batch_impl="packed")),
            ("placement_adaptive_steal",
             dict(pl, placement="adaptive", rebalance_every=4,
                  migrate_cap=64, steal=True)),
        ]
    if workload == "wireless":
        # a *draining* simulation (per-cell arrival budgets exhaust, calls
        # complete, the network empties): the fused loop completes the whole
        # thing — init to empty — in exactly one dispatch, while the host-
        # stepped drive pays one dispatch per epoch of the same window.
        ladder.append(("it4_drain_budget",
                       dict(route="a2a", fused_drain=True, epochs=256,
                            expect_drained=True,
                            model_kw=dict(max_calls=4))))
        # the campaign rung (PR 7): 32 replication seeds of the draining
        # simulation above, run (a) one fused drain per seed and (b) all 32
        # stacked through ONE replication-vmapped while_loop — the whole
        # sweep in a single XLA dispatch.  `epochs` is the drain *bound*, not
        # a window: every replication must actually drain (expect_drained).
        ladder.append(("it5_campaign",
                       dict(route="a2a", campaign=True, reps=32, epochs=256,
                            expect_drained=True,
                            model_kw=dict(max_calls=4),
                            rep_engine_kw=dict(bucket_cap=64, route_cap=2048,
                                               fallback_cap=4096))))
        # the speculation rung (PR 9): the draining simulation above driven
        # at opt_window 0/1/2/4 — epochs-to-drain (while-loop iterations)
        # must fall strictly below the conservative drain at every W, bits
        # identical, rollbacks reported next to the win they price.
        ladder.append(("it6_speculation",
                       dict(route="a2a", speculation=True,
                            windows=[0, 1, 2, 4], epochs=256,
                            expect_drained=True,
                            model_kw=dict(max_calls=4))))
        # the per-device-commit rung (PR 10): the draining simulation at a
        # fixed window, global all-or-nothing vote vs per-device verdict —
        # rolled-back device-windows must strictly shrink, bits identical.
        ladder.append(("it7_per_device_commit",
                       dict(route="a2a", commit_compare=True, opt_window=2,
                            epochs=256, expect_drained=True,
                            model_kw=dict(max_calls=4))))
    if workload == "epidemic":
        # epidemic burns out (finite susceptible pool, absorbing recovered
        # patches) once pop/trans_p stop sustaining the chain — the second,
        # structurally different draining load for the speculation rung:
        # state-dependent arity and ring-local traffic instead of the
        # wireless hotspot.
        ladder.append(("it6_speculation",
                       dict(route="a2a", speculation=True,
                            windows=[0, 1, 2, 4], o=128, epochs=512,
                            expect_drained=True,
                            model_kw=dict(pop=8, n_seeds=16, trans_p=96))))
        # ring-local traffic is the adversarial case for the global vote:
        # stragglers only cross at patch boundaries, so most windows have a
        # straggler-free majority the per-device verdict keeps committed.
        ladder.append(("it7_per_device_commit",
                       dict(route="a2a", commit_compare=True, opt_window=2,
                            o=128, epochs=512, expect_drained=True,
                            model_kw=dict(pop=8, n_seeds=16, trans_p=96))))
    ladder.append(("ltf_reference_scheduler",
                   dict(route="a2a", sched="ltf", epochs=10, warm=2)))
    return ladder


#: tiny CI-smoke scale: every ladder rung must *run* (drivers rot silently
#: otherwise), wall time a few seconds per rung.
SMOKE = dict(o=64, m=8, s=64, epochs=6, warm=2, route_cap=4096)


def build_smoke_ladder(workload: str):
    out = []
    for n, s in build_ladder(workload):
        merged = dict(s, **SMOKE)
        if s.get("expect_drained"):
            # `epochs` on a draining rung is the drain *bound*, not the
            # measured window — clamping it to the smoke window would turn
            # the rung into a guaranteed bound-hit failure.
            merged["epochs"] = s["epochs"]
        if "reps" in s:
            merged["reps"] = min(s["reps"], 8)
        if "windows" in s:
            # one compile per window width — smoke keeps the conservative
            # baseline plus a single speculative width.
            merged["windows"] = [0, 2]
        out.append((n, merged))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--workload", default="phold",
                    help="registered zoo workload (repro/workloads)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, exit nonzero on any rung error "
                         "(CI guard against benchmark-driver rot)")
    ap.add_argument("--rungs", default=None,
                    help="comma-separated rung names to run (default: the "
                         "full ladder); unknown names fail fast")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    D = args.devices
    out = args.out or ("artifacts/pdes_perf.json" if args.workload == "phold"
                       else f"artifacts/pdes_perf_{args.workload}.json")

    failed = []
    results = {}
    ladder = (build_smoke_ladder if args.smoke else build_ladder)(args.workload)
    if args.rungs:
        want = set(args.rungs.split(","))
        if (unknown := want - {n for n, _ in ladder}):
            raise SystemExit(f"[pdes_perf] unknown rungs {sorted(unknown)} — "
                             f"ladder has {[n for n, _ in ladder]}")
        ladder = [(n, s) for n, s in ladder if n in want]
    for name, spec in ladder:
        print(f"[pdes_perf:{args.workload}] {name}...", flush=True)
        results[name] = run_child(D, args.workload, **spec)
        r = results[name]
        if "error" in r:
            print(f"  ERROR {r['error']}")
            failed.append(name)
        else:
            # the full clean-run contract (repro.testing.clean): the child
            # reports every nonzero must-be-zero counter — this parent used
            # to check only 3 of the 6 (fb_overflow/route_overflow dropped
            # events without failing the rung).
            clean = not r.get("unclean")
            if spec.get("campaign"):
                disp = {m: v["dispatches_per_campaign"]
                        for m, v in r["modes"].items()}
                print(f"  {r['ev_s']:,.0f} ev/s aggregate over "
                      f"{r['replications']} replications  "
                      f"dispatches/campaign {disp}  "
                      f"speedup={r['speedup_vs_host_loop']:.2f}x "
                      f"drained={r['drained']} clean={clean}")
            elif spec.get("commit_compare"):
                line = "  ".join(
                    f"{m['opt_commit']}: rb={m['rolled_back_device_windows']}"
                    f" cm={m['committed_device_windows']}"
                    f" iters={m['epochs_to_drain']}"
                    for m in r["modes"].values())
                print(f"  {r['ev_s']:,.0f} ev/s  {line}  "
                      f"(-{r['rollback_reduction']} rolled-back "
                      f"device-windows)  drained={r['drained']} "
                      f"clean={clean}")
            elif spec.get("speculation"):
                line = "  ".join(
                    f"W={w['opt_window']}: {w['epochs_to_drain']} iters "
                    f"(rb={w['rollbacks']})" for w in r["windows"].values())
                print(f"  {r['ev_s']:,.0f} ev/s best  {line}  "
                      f"drained={r['drained']} clean={clean}")
            elif "modes" in r:
                disp = {m: v["dispatches_per_simulation"]
                        for m, v in r["modes"].items()}
                print(f"  {r['ev_s']:,.0f} ev/s  dispatches/simulation "
                      f"{disp}  epochs={r['epochs_run']} "
                      f"drained={r['drained']} clean={clean}")
            else:
                print(f"  {r['ev_s']:,.0f} ev/s  exchange "
                      f"{r['exchange_bytes_per_epoch']/1e6:.2f} MB/epoch "
                      f"stolen={r['stats']['stolen']} "
                      f"rebalances={r['stats']['rebalances']} clean={clean}")
            if not clean:
                print(f"  UNCLEAN {r['unclean']} — run is invalid")
                failed.append(name)
            if spec.get("expect_drained") and r.get("bound_hit"):
                # a draining rung that hit its epoch bound reported ev/s for
                # a simulation that never finished — not a result.
                print(f"  BOUND HIT at epochs={spec['epochs']} with events "
                      f"still in flight — expected a full drain")
                failed.append(name)
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[pdes_perf] wrote {out}")
    if failed:
        raise SystemExit(f"[pdes_perf] FAILED rungs: {failed}")


if __name__ == "__main__":
    main()
