"""§Perf for the paper's own engine: hypothesis→change→measure iterations.

Runs the PARSIR engine hillclimb ladder on CPU (wall-clock events/s) and
reports, for each routing strategy, the *structural* per-epoch exchange bytes
(what the ICI would carry on a pod) — the measurable CPU proxy plus the
analytic collective term.

  PYTHONPATH=src python -m benchmarks.pdes_perf [--devices 8]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

_CHILD = textwrap.dedent("""
    import json, sys, time
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core.engine import AXIS, EngineConfig, ParsirEngine
    from repro.phold.model import Phold, PholdParams

    spec = json.loads(sys.argv[1])
    D = spec["devices"]
    mesh = Mesh(np.array(jax.devices()[:D]), (AXIS,))
    p = PholdParams(n_objects=spec["o"], initial_events=spec["m"],
                    state_nodes=spec["s"], realloc_fraction=0.004,
                    lookahead=spec["la"], dist=spec["dist"],
                    hot_objects=spec.get("hot_o", 0),
                    hot_prob=spec.get("hot_p", 0))
    model = Phold(p)
    cfg = EngineConfig(lookahead=p.lookahead,
                       epoch_len=spec.get("epoch_len"),
                       n_buckets=32, bucket_cap=spec.get("bucket_cap", 256),
                       route_cap=spec["route_cap"], fallback_cap=16384,
                       route=spec["route"], scheduler=spec.get("sched","batch"),
                       steal=spec.get("steal", False), steal_cap=8,
                       claim_cap=16,
                       batch_impl=spec.get("batch_impl", "rounds"))
    eng = ParsirEngine(model, cfg, mesh=mesh)
    st = eng.run(eng.init(), spec.get("warm", 6))
    base = eng.totals(st)["processed"]
    t0 = time.perf_counter()
    st = eng.run(st, spec["epochs"])
    st.stats.processed.block_until_ready()
    dt = time.perf_counter() - t0
    tot = eng.totals(st)
    n = tot["processed"] - base
    # structural exchange bytes per epoch: record bytes are 17B/event
    # (dst4 ts4 seed4 payload4 valid1)
    rec_b = 17
    if spec["route"] == "allgather":
        ex = D * D * spec["route_cap"] * rec_b          # D bufs to D devices
    else:
        ex = D * spec["route_cap"] * rec_b              # pairwise a2a
    if spec.get("steal"):
        state_b = p.state_nodes * (p.lanes * 4 + 4) + 8
        loan_b = 8 * (cfg.bucket_cap * 12 + state_b)
        ex += 2 * D * D * loan_b                        # publish + return
    print(json.dumps({"ev_s": n / dt, "n": n, "dt": dt, "stats": tot,
                      "exchange_bytes_per_epoch": ex}))
""")

BASE = dict(o=512, m=40, s=256, la=0.5, dist="exponential", route_cap=8192,
            epochs=30)


def run_child(devices: int, **spec):
    merged = dict(BASE, devices=devices, **spec)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _CHILD, json.dumps(merged)],
                       env=env, capture_output=True, text=True, timeout=2400)
    if r.returncode != 0:
        return {"error": r.stderr[-300:]}
    return json.loads(r.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--out", default="artifacts/pdes_perf.json")
    args = ap.parse_args()
    D = args.devices

    ladder = [
        ("baseline_paper_faithful", dict(route="allgather")),
        ("it1_route_a2a", dict(route="a2a")),
        ("it2_epoch_half_L", dict(route="a2a", epoch_len=0.25)),
        ("skew_baseline_nosteal", dict(route="a2a", hot_o=32, hot_p=96,
                                       bucket_cap=512)),
        ("skew_it3_steal", dict(route="a2a", hot_o=32, hot_p=96,
                                bucket_cap=512, steal=True)),
        ("ltf_reference_scheduler", dict(route="a2a", sched="ltf", epochs=10,
                                         warm=2)),
    ]
    results = {}
    for name, spec in ladder:
        print(f"[pdes_perf] {name}...", flush=True)
        results[name] = run_child(D, **spec)
        r = results[name]
        if "error" in r:
            print(f"  ERROR {r['error']}")
        else:
            clean = (r["stats"]["late_events"] == 0
                     and r["stats"]["cal_overflow"] == 0)
            print(f"  {r['ev_s']:,.0f} ev/s  "
                  f"exchange {r['exchange_bytes_per_epoch']/1e6:.2f} MB/epoch "
                  f"stolen={r['stats']['stolen']} clean={clean}")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[pdes_perf] wrote {args.out}")


if __name__ == "__main__":
    main()
