"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference.

On this CPU container interpret-mode timing is NOT TPU performance — the
numbers recorded here are correctness-path timings; TPU perf is reasoned
structurally in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(rows):
    rng = np.random.default_rng(0)

    # event_apply
    n, LANES, S, C = 32, 6, 512, 16
    payload = jnp.asarray(rng.random((n, LANES, S), np.float32))
    addresses = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (n, S))
    top = jnp.full((n,), S, jnp.int32)
    ts = jnp.asarray(np.sort(rng.random((n, C)).astype(np.float32), axis=1))
    seed = jnp.asarray(rng.integers(0, 2**32, (n, C), dtype=np.uint32))
    cnt = jnp.full((n,), C, jnp.int32)
    kw = dict(n_objects=64, lookahead=0.5, K=S // 32, KR=3, dist="dyadic")
    for impl, flag in (("pallas_interp", True), ("jnp_ref", False)):
        f = jax.jit(lambda *a: ops.event_apply(*a, **kw, use_pallas=flag))
        dt = _time(f, payload, addresses, top, ts, seed, cnt)
        rows.append({"name": f"kernel_event_apply_{impl}",
                     "us_per_call": 1e6 * dt,
                     "derived": f"events={n*C} shape=({n},{LANES},{S})x{C}"})

    # flash attention
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    for impl, flag in (("pallas_interp", True), ("jnp_ref", False)):
        f = jax.jit(lambda a, b, c: ops.mha(a, b, c, causal=True, bq=128,
                                            bk=128, use_pallas=flag))
        dt = _time(f, q, k, v)
        rows.append({"name": f"kernel_flash_attn_{impl}",
                     "us_per_call": 1e6 * dt,
                     "derived": "shape=B1 Hq4 Hkv2 T256 D64"})

    # ssd
    x = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.float32) * 0.5
    dtt = jnp.asarray(rng.random((1, 256, 4)), jnp.float32) * 0.2
    A = -jnp.asarray(rng.random((4,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((1, 256, 32)), jnp.float32) * 0.3
    Cm = jnp.asarray(rng.standard_normal((1, 256, 32)), jnp.float32) * 0.3
    for impl, flag in (("pallas_interp", True), ("seq_ref", False)):
        f = jax.jit(lambda *a: ops.ssd(*a, chunk=64, use_pallas=flag))
        dt = _time(f, x, dtt, A, B, Cm)
        rows.append({"name": f"kernel_ssd_{impl}",
                     "us_per_call": 1e6 * dt,
                     "derived": "shape=B1 T256 H4 P64 N32"})
    return rows
