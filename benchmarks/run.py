"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (paper Figs 2–5 analogues + kernel
micro-benches).  ``--quick`` skips the subprocess scaling sweep.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip subprocess strong-scaling (fig3)")
    args, _ = ap.parse_known_args()

    from . import (engine_comparison, kernel_bench, phold_modelsize,
                   phold_scaling, phold_speed)

    rows: list[dict] = []
    print("# PARSIR benchmarks (paper figure analogues)", file=sys.stderr)
    for name, mod in [("fig2 speed vs L,M", phold_speed),
                      ("fig4 model size", phold_modelsize),
                      ("fig5 engine comparison", engine_comparison),
                      ("kernels", kernel_bench)]:
        print(f"# running {name}...", file=sys.stderr, flush=True)
        mod.run(rows)
    if not args.quick:
        print("# running fig3 strong scaling (subprocesses)...",
              file=sys.stderr, flush=True)
        phold_scaling.run(rows)

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")


if __name__ == "__main__":
    main()
