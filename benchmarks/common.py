"""Shared benchmark helpers: engine construction + throughput measurement."""
from __future__ import annotations

import time

from repro.core.engine import EngineConfig, ParsirEngine
from repro.phold.model import Phold, PholdParams


def build(o=512, m=20, s=512, p=0.004, lookahead=0.5, dist="exponential",
          scheduler="batch", epoch_len=None, bucket_cap=256, route_cap=32768,
          fallback_cap=32768, mesh=None, **kw):
    model = Phold(PholdParams(n_objects=o, initial_events=m, state_nodes=s,
                              realloc_fraction=p, lookahead=lookahead,
                              dist=dist))
    cfg = EngineConfig(lookahead=lookahead, epoch_len=epoch_len,
                       n_buckets=16, bucket_cap=bucket_cap,
                       route_cap=route_cap, fallback_cap=fallback_cap,
                       scheduler=scheduler, **kw)
    return ParsirEngine(model, cfg, mesh=mesh)


def throughput(eng, warmup_epochs=10, epochs=40):
    """Events/second over a timed run (post-warmup/compile)."""
    st = eng.init()
    st = eng.run(st, warmup_epochs)          # compile + warm
    before = eng.totals(st)["processed"]
    t0 = time.perf_counter()
    st = eng.run(st, epochs)
    for l in (st.stats.processed,):
        l.block_until_ready()
    dt = time.perf_counter() - t0
    tot = eng.totals(st)
    n = tot["processed"] - before
    clean = (tot["cal_overflow"] == 0 and tot["late_events"] == 0
             and tot["route_overflow"] == 0 and tot["fb_overflow"] == 0)
    return n / dt, n, dt, clean
