"""Paper Fig 5 analogue: PARSIR's batch scheduler vs the interleaving
lowest-timestamp-first scheduler (ROOT-Sim/USE-style, same engine substrate)
vs the sequential heap engine — paper's adverse configuration (min L, min M)."""
from __future__ import annotations

import time

from repro.core.ref_engine import run_sequential
from repro.phold.model import Phold, PholdParams

from .common import build, throughput

_CFG = dict(o=256, m=10, s=500, p=0.004, lookahead=0.1, dist="exponential")
# denser configuration where per-object batches are non-trivial (the regime
# the paper's batching argument addresses; the adverse config has ~1 event
# per object per epoch, so batching degenerates by construction)
_CFG_DENSE = dict(o=256, m=100, s=500, p=0.004, lookahead=0.5,
                  dist="exponential")


def run(rows):
    for tag, cfg, epochs in (("adverse", _CFG, 30), ("dense", _CFG_DENSE, 12)):
        for sched in ("batch", "ltf"):
            eng = build(scheduler=sched, bucket_cap=512, **cfg)
            ev_s, n, dt, clean = throughput(eng, warmup_epochs=3,
                                            epochs=epochs)
            rows.append({
                "name": f"fig5_engine_{sched}_{tag}",
                "us_per_call": 1e6 * dt / max(n, 1),
                "derived": f"events_per_s={ev_s:.0f} n={n} clean={clean}",
            })

    # sequential heap oracle (the no-parallelism floor)
    model = Phold(PholdParams(n_objects=_CFG["o"], initial_events=_CFG["m"],
                              state_nodes=_CFG["s"],
                              realloc_fraction=_CFG["p"],
                              lookahead=_CFG["lookahead"],
                              dist="exponential"))
    t0 = time.perf_counter()
    res = run_sequential(model, 35, _CFG["lookahead"])
    dt = time.perf_counter() - t0
    rows.append({
        "name": "fig5_engine_sequential",
        "us_per_call": 1e6 * dt / max(res.total_processed, 1),
        "derived": f"events_per_s={res.total_processed/dt:.0f} "
                   f"n={res.total_processed}",
    })
    return rows
