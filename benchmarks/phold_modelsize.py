"""Paper Fig 4 analogue: PHOLD throughput vs model size O (objects), fixed
worker count — a well-structured engine should stay ~flat."""
from __future__ import annotations

from .common import build, throughput


def run(rows):
    for o in (128, 256, 512, 1024):
        eng = build(o=o, m=20, s=256, p=0.004, lookahead=0.5,
                    dist="exponential")
        ev_s, n, dt, clean = throughput(eng, warmup_epochs=5, epochs=30)
        rows.append({
            "name": f"fig4_modelsize_O{o}",
            "us_per_call": 1e6 * dt / max(n, 1),
            "derived": f"events_per_s={ev_s:.0f} n={n} clean={clean}",
        })
    return rows
