"""Sharded checkpointing: save/restore arbitrary pytrees with resharding.

Layout:  <dir>/step_<N>/
            manifest.json          — pytree structure, shapes, dtypes
            leaf_<i>.npy           — one file per leaf (full logical array)
         <dir>/LATEST              — atomic pointer (rename-into-place)

Restore accepts a *different* mesh than the one that saved (elastic scaling):
leaves are loaded as numpy and re-placed under the target shardings — the
checkpoint is the resharding point, exactly how pod-count changes roll through
a real fleet.  Writes are atomic (tmp dir + rename) so a failure mid-save
never corrupts LATEST.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    import jax.tree_util as jtu
    leaves, treedef = jtu.tree_flatten(tree)
    return leaves, treedef


def save(directory: str | os.PathLike, step: int, tree: Any,
         keep: int = 3) -> Path:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(tmp / f"leaf_{i}.npy", arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))

    final = d / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)

    latest_tmp = d / ".LATEST.tmp"
    latest_tmp.write_text(str(step))
    latest_tmp.rename(d / "LATEST")     # atomic pointer flip

    _gc(d, keep)
    return final


def _gc(d: Path, keep: int):
    steps = sorted((int(p.name.split("_")[1]) for p in d.glob("step_*")),
                   reverse=True)
    for s in steps[keep:]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    f = Path(directory) / "LATEST"
    if not f.exists():
        return None
    return int(f.read_text().strip())


def restore(directory: str | os.PathLike, tree_like: Any,
            step: int | None = None, shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; optionally place leaves
    under ``shardings`` (pytree of Shardings matching tree_like) — this is the
    elastic-reshard path."""
    d = Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {d}")
    src = d / f"step_{step}"
    manifest = json.loads((src / "manifest.json").read_text())

    leaves, treedef = _flatten(tree_like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(f"checkpoint has {manifest['n_leaves']} leaves, "
                         f"target structure has {len(leaves)}")
    shard_leaves = (None if shardings is None
                    else treedef.flatten_up_to(shardings))

    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(src / f"leaf_{i}.npy")
        want = tuple(getattr(ref, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"target {want}")
        if shard_leaves is not None and shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), step
