"""Sharding rules: logical axes → mesh axes, safe constraint helpers.

Logical names used across the stack:
  batch   → ("pod", "data")   activations' leading batch dim
  vocab   → "model"           embedding / logits vocab dim
  heads   → "model"           attention heads (when divisible)
  ffn     → "model"           MLP hidden dim
  expert  → "model"           MoE expert dim
  capacity→ "data"            MoE expert-buffer capacity dim

``maybe_constraint`` degrades to identity when there is no ambient mesh (CPU
unit tests) or when the requested axes don't exist/divide — so model code can
be written once and run anywhere.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def ambient_mesh() -> Optional[Mesh]:
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def _resolve(axis, mesh: Mesh):
    """Map a logical spec entry onto the mesh, dropping absent axes."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        got = tuple(a for a in axis if a in mesh.axis_names)
        return got if got else None
    return axis if axis in mesh.axis_names else None


def logical(*axes) -> P:
    """Build a PartitionSpec against the ambient mesh from logical entries,
    dropping axes the mesh doesn't have."""
    mesh = ambient_mesh()
    if mesh is None:
        return P()
    return P(*(_resolve(a, mesh) for a in axes))


def maybe_constraint(x, *axes):
    """with_sharding_constraint that is a no-op without a mesh and drops
    non-divisible axes.  The literal BATCH tuple is remapped per sharding
    mode (fsdp shards batch over every axis)."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    axes = tuple(batch_axes() if (isinstance(a, tuple) and tuple(a) == BATCH)
                 else a for a in axes)
    resolved = []
    for dim, a in enumerate(axes):
        r = _resolve(a, mesh)
        if r is not None:
            size = int(np.prod([mesh.shape[n] for n in
                                (r if isinstance(r, tuple) else (r,))]))
            if x.shape[dim] % size != 0:
                r = None
        resolved.append(r)
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except Exception:
        return x


BATCH = ("pod", "data")
_MODE = {"value": "megatron"}


def set_mode(mode: str):
    """megatron: TP over 'model', batch over ('pod','data').
    fsdp: ZeRO-3 — params sharded over every axis on their largest divisible
    dim; batch/activations sharded over ALL axes; no tensor parallelism."""
    _MODE["value"] = mode


def get_mode() -> str:
    return _MODE["value"]


def batch_axes():
    return ("pod", "data", "model") if _MODE["value"] == "fsdp" else BATCH


def batch_spec() -> P:
    return logical(batch_axes())


def use_param(w):
    """ZeRO-3 use-site materialization: under fsdp mode, constrain a stored-
    sharded weight to replicated right before its dot — GSPMD then emits the
    per-layer weight all-gather (and the matching grad reduce-scatter in the
    backward), instead of gathering activations (the v4 failure mode)."""
    if _MODE["value"] != "fsdp":
        return w
    mesh = ambient_mesh()
    if mesh is None:
        return w
    try:
        return jax.lax.with_sharding_constraint(w, P(*([None] * w.ndim)))
    except Exception:
        return w


# -- parameter sharding rules ---------------------------------------------------

_RULES = [
    # (path substring match, spec builder by array ndim)
    ("embed/tok", lambda nd: _pad(P("model", None), nd)),
    ("embed/head", lambda nd: _pad(P(None, "model"), nd)),
    ("patch_proj", lambda nd: _pad(P(None, None), nd)),
    ("attn/wq", lambda nd: _pad(P(None, "model"), nd)),
    ("attn/wk", lambda nd: _pad(P(None, "model"), nd)),
    ("attn/wv", lambda nd: _pad(P(None, "model"), nd)),
    ("attn/wo", lambda nd: _pad(P("model", None), nd)),
    ("attn/wdkv", lambda nd: _pad(P(None, None), nd)),
    ("attn/wkr", lambda nd: _pad(P(None, None), nd)),
    ("attn/wukv", lambda nd: _pad(P(None, "model"), nd)),
    ("moe/router", lambda nd: _pad(P(None, None), nd)),
    # expert-FSDP: experts shard over "model", the ff dim over "data" — a 1T
    # MoE's weights spread over the full chip grid, not just the TP axis.
    ("moe/wg", lambda nd: _pad(P("model", None, "data"), nd, expert=True)),
    ("moe/wu", lambda nd: _pad(P("model", None, "data"), nd, expert=True)),
    ("moe/wd", lambda nd: _pad(P("model", "data", None), nd, expert=True)),
    ("shared/wg", lambda nd: _pad(P(None, "model"), nd)),
    ("shared/wu", lambda nd: _pad(P(None, "model"), nd)),
    ("shared/wd", lambda nd: _pad(P("model", None), nd)),
    ("mlp/wg", lambda nd: _pad(P(None, "model"), nd)),
    ("mlp/wu", lambda nd: _pad(P(None, "model"), nd)),
    ("mlp/wd", lambda nd: _pad(P("model", None), nd)),
    # zamba shared attention / mlstm / mamba projections
    ("wq", lambda nd: _pad(P(None, "model"), nd)),
    ("wk", lambda nd: _pad(P(None, "model"), nd)),
    ("wv", lambda nd: _pad(P(None, "model"), nd)),
    ("wo", lambda nd: _pad(P("model", None), nd)),
    ("wg", lambda nd: _pad(P(None, "model"), nd)),
    ("wu", lambda nd: _pad(P(None, "model"), nd)),
    ("wd", lambda nd: _pad(P("model", None), nd)),
    ("wup", lambda nd: _pad(P(None, "model"), nd)),
    ("wdown", lambda nd: _pad(P("model", None), nd)),
    ("win", lambda nd: _pad(P(None, "model"), nd)),
    ("wout", lambda nd: _pad(P("model", None), nd)),
    ("wproj", lambda nd: _pad(P("model", None), nd)),
    ("wx", lambda nd: _pad(P(None, "model"), nd)),
]


def _pad(spec: P, nd: int, expert: bool = False) -> P:
    """Left-pad a spec with None for stacked leading dims (scan layers)."""
    pad = nd - len(spec)
    if pad < 0:
        return P(*tuple(spec)[-nd:])
    return P(*([None] * pad + list(spec)))


def param_spec(path: str, ndim: int) -> P:
    for frag, builder in _RULES:
        if frag in path:
            return builder(ndim)
    return P(*([None] * ndim))


def _path_str(kp) -> str:
    import jax.tree_util as jtu
    parts = []
    for k in kp:
        if isinstance(k, jtu.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jtu.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jtu.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def params_shardings(params_shape: Any, mesh: Mesh, mode: str | None = None):
    """NamedShardings for a params pytree (works on ShapeDtypeStructs)."""
    import jax.tree_util as jtu
    mode = mode or _MODE["value"]

    if mode == "fsdp":
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes]))

        def spec_fsdp(kp, leaf):
            # shard the largest divisible dim over ALL axes (ZeRO-3)
            cands = [(s, i) for i, s in enumerate(leaf.shape)
                     if s % size == 0 and s >= size]
            spec = [None] * len(leaf.shape)
            if cands:
                _, dim = max(cands)
                spec[dim] = axes
            return NamedSharding(mesh, P(*spec))

        return jtu.tree_map_with_path(spec_fsdp, params_shape)

    def spec_for(kp, leaf):
        path = _path_str(kp)
        sp = param_spec(path, len(leaf.shape))
        # drop axes that don't divide
        fixed = []
        for dim, a in enumerate(tuple(sp)):
            if a is None:
                fixed.append(None)
                continue
            names = a if isinstance(a, tuple) else (a,)
            if any(n not in mesh.axis_names for n in names):
                fixed.append(None)
                continue
            size = int(np.prod([mesh.shape[n] for n in names]))
            fixed.append(a if leaf.shape[dim] % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jtu.tree_map_with_path(spec_for, params_shape)


def batch_shardings(batch_shape: Any, mesh: Mesh, mode: str | None = None):
    mode = mode or _MODE["value"]
    src = ("pod", "data", "model") if mode == "fsdp" else BATCH

    def spec_for(leaf):
        names = tuple(a for a in src if a in mesh.axis_names)
        if not names:
            return NamedSharding(mesh, P())
        size = int(np.prod([mesh.shape[n] for n in names]))
        lead = names if leaf.shape and leaf.shape[0] % size == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (len(leaf.shape) - 1))))
    return jax.tree.map(spec_for, batch_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
