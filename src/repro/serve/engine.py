"""Serving: prefill + one-token decode step factories and cache shardings.

Cache sharding rule (documented in DESIGN.md): the batch-sized dim shards over
("pod","data"); the largest remaining dim divisible by the "model" axis shards
over "model" — for GQA KV caches that is the sequence dim (context-parallel
cache) or the kv-head dim, for MLA the latent sequence, for SSM states the
feature dims.  This keeps every decode shape within per-device HBM.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def cache_shardings(cache_shape: Any, mesh: Mesh, batch_size: int):
    bnames = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = int(np.prod([mesh.shape[n] for n in bnames])) if bnames else 1
    msize = mesh.shape.get("model", 1)

    def spec_for(leaf):
        spec = [None] * len(leaf.shape)
        bdim = None
        for i, s in enumerate(leaf.shape):
            if s == batch_size and bnames and s % bsize == 0:
                spec[i] = bnames
                bdim = i
                break
        if "model" in mesh.axis_names and msize > 1:
            cands = [(s, i) for i, s in enumerate(leaf.shape)
                     if i != bdim and s % msize == 0 and s >= msize]
            if cands:
                _, mdim = max(cands)
                spec[mdim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(spec_for, cache_shape)


def make_decode_step(model):
    def decode_step(params, tokens, caches, cur_len):
        return model.decode_step(params, tokens, caches, cur_len)
    return decode_step


def make_prefill(model):
    def prefill(params, batch, caches):
        return model.prefill(params, batch, caches)
    return prefill


class ServeSession:
    """Minimal batched serving loop (greedy), used by examples/serve_lm.py."""

    def __init__(self, model, params, batch_size: int, max_len: int,
                 dtype=jnp.bfloat16):
        self.model, self.params = model, params
        self.caches = model.init_cache(batch_size, max_len, dtype)
        self._decode = jax.jit(make_decode_step(model))
        self._prefill = jax.jit(make_prefill(model))
        self.cur_len = 0

    def prefill(self, batch):
        logits, self.caches = self._prefill(self.params, batch, self.caches)
        first = next(iter(batch.values()))
        self.cur_len = int(first.shape[1])
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1))

    def decode(self, tokens_np, n_steps: int):
        toks = jnp.asarray(tokens_np, jnp.int32)[:, None]
        out = []
        for _ in range(n_steps):
            logits, self.caches = self._decode(self.params, toks, self.caches,
                                               jnp.int32(self.cur_len))
            toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(np.asarray(toks[:, 0]))
            self.cur_len += 1
        return np.stack(out, axis=1)
