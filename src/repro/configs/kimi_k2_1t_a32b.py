"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

Per the assignment table this uses GQA (kv=8) attention; the released K2 uses
MLA — we follow the table (noted in DESIGN.md).  1 shared expert (K2 style).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=128,
    activation="swiglu", norm="rms", rope_theta=50_000.0,
    n_experts=384, experts_per_token=8, n_shared_experts=1, moe_d_ff=2048,
    capacity_factor=1.25,
    # 1T params: bf16 master weights + bf16 Adam moments are the only way the
    # state approaches the 512-chip HBM budget (see EXPERIMENTS.md §Dry-run).
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, n_experts=8, experts_per_token=2,
        n_shared_experts=1, moe_d_ff=64, remat="none", dtype="float32")
