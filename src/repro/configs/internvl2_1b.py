"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2.  [arXiv:2404.16821; hf]

Backbone (InternLM2-ish) only: the InternViT frontend is a STUB —
input_specs() provides precomputed patch embeddings [B, n_patches, d] that the
model projects and prepends to the token sequence; loss masks image positions."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    activation="swiglu", norm="rms", rope_theta=10_000.0,
    frontend="vision", n_patches=256,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, n_patches=8, remat="none", dtype="float32")
