"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H (MLA) d_ff=1408
vocab=102400, MoE 64e top-6 — MLA kv_lora=512, 2 shared + routed top-6.
[arXiv:2405.04434; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    activation="swiglu", norm="rms", rope_theta=10_000.0,
    n_experts=64, experts_per_token=6, n_shared_experts=2, moe_d_ff=1408,
    use_mla=True, kv_lora_rank=512, rope_head_dim=64,
    capacity_factor=1.25,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab_size=256, n_experts=8, experts_per_token=2,
        n_shared_experts=2, moe_d_ff=64, kv_lora_rank=32, rope_head_dim=8,
        remat="none", dtype="float32")
