"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (7:1).  [arXiv:2405.04517; unverified]

O(1) recurrent state → runs the long_500k shape."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    norm="rms", slstm_every=8, mlstm_chunk=128,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=2,
        vocab_size=256, slstm_every=3, mlstm_chunk=16, remat="none",
        dtype="float32")
