"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a STUB — input_specs() provides
precomputed frame embeddings [B, T, d]; the LM head predicts codebook tokens
(vocab 2048)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    activation="gelu", norm="ln", rope_theta=10_000.0,
    frontend="audio",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, remat="none", dtype="float32")
