"""Arch registry: ``--arch <id>`` → ModelConfig (full or reduced)."""
from __future__ import annotations

from importlib import import_module

ARCHS = {
    "granite-3-2b": "granite_3_2b",
    "stablelm-12b": "stablelm_12b",
    "starcoder2-7b": "starcoder2_7b",
    "llama3.2-3b": "llama3_2_3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "musicgen-medium": "musicgen_medium",
    "internvl2-1b": "internvl2_1b",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-1.2b": "zamba2_1_2b",
}

# archs whose attention is quadratic-only → long_500k is skipped (DESIGN.md).
FULL_ATTENTION_ONLY = {
    "granite-3-2b", "stablelm-12b", "starcoder2-7b", "llama3.2-3b",
    "kimi-k2-1t-a32b", "deepseek-v2-lite-16b", "musicgen-medium",
    "internvl2-1b",
}


def get_config(arch: str, reduced: bool = False):
    mod = import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.reduced() if reduced else mod.CONFIG


def all_archs():
    return list(ARCHS)
