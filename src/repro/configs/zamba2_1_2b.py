"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 + shared attn blocks.  [arXiv:2411.15242; hf]

Hybrid (O(1) Mamba state + shared-attn KV) → runs the long_500k shape."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=128,
    norm="rms", rope_theta=10_000.0,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, attn_every=6,
    ssd_chunk=128,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16, attn_every=2,
        ssd_chunk=16, remat="none", dtype="float32")
