"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152, head_dim=128,
    activation="gelu", norm="ln", rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, remat="none", dtype="float32")
