"""Config schema: model architecture, input shapes, mesh, train/serve knobs."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | xlstm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads
    activation: str = "swiglu"      # swiglu | gelu
    norm: str = "rms"               # rms | ln
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0     # leading dense layers before MoE starts
    capacity_factor: float = 1.25

    # MLA (DeepSeek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 64

    # SSM / recurrent
    ssm_state: int = 0              # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64          # P
    ssm_conv: int = 4
    attn_every: int = 0             # zamba: shared attn block interval
    slstm_every: int = 0            # xlstm: 1-in-k blocks are sLSTM

    # frontend stub (audio/vlm): model consumes precomputed embeddings
    frontend: Optional[str] = None  # None | audio | vision
    n_patches: int = 256            # vision: patches prepended to text

    # execution
    scan_layers: bool = True
    remat: str = "full"             # none | full | dots
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    attn_impl: str = "jnp"          # jnp | pallas
    ssd_chunk: int = 128
    mlstm_chunk: int = 128
    attn_chunk: int = 1024          # KV block for chunked attention
    logits_fp32: bool = True        # False → bf16 logits (halves loss temps)
    attn_f32: bool = True           # False → bf16 attention compute (f32 stats)
    mlstm_bf16: bool = False        # bf16 chunk intermediates, f32 accum
    moe_buf_layout: str = "md"      # expert-buffer constraint: md | m | none
    sharding_mode: str = "megatron"  # megatron (TP) | fsdp (ZeRO-3 over all axes)
    decode_attn: str = "gather"     # gather (XLA default) | sp (flash-decoding:
                                    # partial softmax over the S-sharded cache,
                                    # psum-merged — no cache all-gather)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe"):
            if self.use_mla:
                r, rd = self.kv_lora_rank, self.rope_head_dim
                per_layer += d * (Hq * (hd + rd))            # q proj
                per_layer += d * r + d * rd                  # kv down + k_rope
                per_layer += r * Hq * (hd + hd)              # kv up (k_nope, v)
                per_layer += Hq * hd * d                     # o proj
            else:
                per_layer += d * Hq * hd + 2 * d * Hkv * hd + Hq * hd * d
            n_mat = 3 if self.activation == "swiglu" else 2
            if self.family == "moe":
                moe_layers = L - self.first_dense_layers
                dense_layers = self.first_dense_layers
                per_layer = per_layer  # attn for all layers
                ffn_moe = (self.n_experts * n_mat * d * self.moe_d_ff
                           + self.n_shared_experts * n_mat * d * self.moe_d_ff
                           + d * self.n_experts)
                ffn_dense = n_mat * d * self.d_ff
                total = emb + L * per_layer + moe_layers * ffn_moe \
                    + dense_layers * ffn_dense
                return total
            per_layer += n_mat * d * self.d_ff
        elif self.family == "xlstm":
            di = 2 * d
            per_layer = d * di * 2 + di * d + 3 * di  # up(x2), down, gates-ish
        elif self.family == "hybrid":
            di = self.d_inner
            per_layer = (d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                         + di * d + self.ssm_conv * di)
            n_shared = max(1, L // max(1, self.attn_every))
            shared = (2 * d) * 3 * d + d * d + 3 * (2 * d) * self.d_ff // 2
            return emb + L * per_layer + shared
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        n_mat = 3 if self.activation == "swiglu" else 2
        full = self.param_count()
        moe_layers = L - self.first_dense_layers
        all_experts = moe_layers * self.n_experts * n_mat * d * self.moe_d_ff
        active_experts = moe_layers * self.experts_per_token * n_mat * d * self.moe_d_ff
        return full - all_experts + active_experts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatch: int = 0              # 0 → no accumulation
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "ckpt"
    keep_checkpoints: int = 3
