import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell on
the production mesh built from 512 placeholder host devices.

For each cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis (HLO FLOPs/bytes) and per-collective byte sums
parsed from the post-SPMD HLO — the inputs to the §Roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --all --mesh multi --skip-collectives
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES
from ..configs.registry import all_archs
from ..distributed.sharding import batch_shardings, params_shardings
from ..launch.mesh import make_production_mesh
from ..launch.specs import cell_is_skipped, input_specs
from ..serve.engine import cache_shardings, make_decode_step, make_prefill
from ..train.step import make_train_step

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\])")
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|branches)=\{?%?([\w.\-]+)")


def collective_bytes(hlo_text: str) -> dict:
    """Trip-count-exact collective accounting over post-SPMD HLO.

    XLA prints a scan's `while` body computation once, but it executes
    trip-count times (recorded in ``backend_config={"known_trip_count":...}``).
    We build the computation call graph (whiles x trip, calls x 1) and scale
    each collective's bytes by its computation's effective multiplier, so
    per-layer collectives inside the layer scan count n_layers times — see
    EXPERIMENTS.md §Roofline methodology (validated against unrolled HLO).
    """
    comps: dict[str, dict] = {}
    entry = None
    current = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if line and line[0] not in " \t" and line.endswith("{") \
                and ("(" in line or line.startswith(("ENTRY", "%"))):
            s = line.strip()
            is_entry = s.startswith("ENTRY")
            if is_entry:
                s = s[len("ENTRY"):].strip()
            name = s.lstrip("%").split("(")[0].split()[0].rstrip()
            if not name or name == "HloModule":
                current = None
                continue
            comps[name] = {"colls": [], "whiles": [], "calls": []}
            current = name
            if is_entry:
                entry = name
            continue
        if current is None or current not in comps:
            continue
        if " while(" in line:
            m = _BODY_RE.search(line)
            if m:
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                comps[current]["whiles"].append((m.group(1), trip))
            continue
        cm = _CALLS_RE.search(line)
        if cm and " while(" not in line:
            comps[current]["calls"].append(cm.group(1))
        for c in _COLLECTIVES:
            if f" {c}(" in line or f"{c}-start(" in line:
                if f"{c}-done(" in line:
                    continue
                m = _SHAPE_RE.search(line)
                if not m:
                    continue
                total = 0
                if m.group(1) is not None:
                    for dt, dims in _TUPLE_ELEM_RE.findall(m.group(1)):
                        total += _shape_bytes(dt, dims)
                else:
                    total = _shape_bytes(m.group(2), m.group(3))
                comps[current]["colls"].append((c, total))
                break

    # effective multiplier per computation (DAG walk from ENTRY)
    mult: dict[str, float] = {}
    if entry is not None:
        stack = [(entry, 1.0)]
        while stack:
            name, m = stack.pop()
            if name not in comps:
                continue
            mult[name] = max(mult.get(name, 0.0), m)
            for body, trip in comps[name]["whiles"]:
                stack.append((body, m * trip))
            for callee in comps[name]["calls"]:
                stack.append((callee, m))

    out = {c: {"bytes": 0, "count": 0, "scaled_bytes": 0.0}
           for c in _COLLECTIVES}
    for name, info in comps.items():
        m = mult.get(name, 1.0)
        for c, b in info["colls"]:
            out[c]["bytes"] += b
            out[c]["count"] += 1
            out[c]["scaled_bytes"] += b * m
    return out


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        out[k] = v
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             skip_collectives: bool = False, mesh=None,
             overrides: dict | None = None) -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "ok"}
    if overrides:
        rec["overrides"] = dict(overrides)
    reason = cell_is_skipped(arch, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        return rec

    if mesh is None:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["n_devices"] = int(np.prod(list(mesh.shape.values())))
    spec = input_specs(arch, shape_name, overrides=overrides)
    cfg, model = spec["cfg"], spec["model"]
    from ..distributed.sharding import set_mode
    set_mode(getattr(cfg, "sharding_mode", "megatron"))

    t0 = time.time()
    with mesh:
        psh = params_shardings(spec["params"], mesh)
        if spec["kind"] == "train":
            from ..configs.base import TrainConfig
            tkw = {k[6:]: v for k, v in (overrides or {}).items()
                   if k.startswith("train.")}
            gsh = psh if (overrides or {}).get("_grad_shard") else None
            step_fn = make_train_step(model, TrainConfig(**tkw),
                                      grad_shardings=gsh)
            osh = params_shardings(spec["opt_state"], mesh)
            bsh = batch_shardings(spec["batch"], mesh)
            jfn = jax.jit(step_fn, in_shardings=(psh, osh, bsh),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(spec["params"], spec["opt_state"],
                                spec["batch"])
        elif spec["kind"] == "prefill":
            fn = make_prefill(model)
            bsh = batch_shardings(spec["batch"], mesh)
            bs = SHAPES[shape_name].global_batch
            csh = cache_shardings(spec["caches"], mesh, bs)
            jfn = jax.jit(fn, in_shardings=(psh, bsh, csh),
                          donate_argnums=(2,))
            lowered = jfn.lower(spec["params"], spec["batch"], spec["caches"])
        else:
            fn = make_decode_step(model)
            bs = SHAPES[shape_name].global_batch
            csh = cache_shardings(spec["caches"], mesh, bs)
            tsh = batch_shardings({"t": spec["tokens"]}, mesh)["t"]
            rsh = NamedSharding(mesh, P())
            jfn = jax.jit(fn, in_shardings=(psh, tsh, csh, rsh),
                          donate_argnums=(2,))
            cur = jax.ShapeDtypeStruct((), np.int32)
            lowered = jfn.lower(spec["params"], spec["tokens"],
                                spec["caches"], cur)
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(ma, attr, None)
                if v is not None:
                    rec[attr] = int(v)
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and (k in ("flops", "transcendentals")
                                     or k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = str(e)

    if not skip_collectives:
        t2 = time.time()
        try:
            txt = compiled.as_text()
            rec["collectives"] = collective_bytes(txt)
            rec["hlo_lines"] = txt.count("\n")
        except Exception as e:  # pragma: no cover
            rec["collectives_error"] = str(e)
        rec["parse_s"] = round(time.time() - t2, 2)

    # model params (analytic) for §Roofline MODEL_FLOPS = 6 N D
    rec["param_count"] = int(cfg.param_count())
    rec["active_param_count"] = int(cfg.active_param_count())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-collectives", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="tag for hillclimb runs (adds __<variant> to files)")
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig field=value (train.* → TrainConfig)")
    args = ap.parse_args()
    overrides = _parse_overrides(args.override)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    cells = []
    if args.all:
        for a in all_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(args.arch, s) for s in shapes]

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{args.mesh}"
        if args.variant:
            tag += f"__{args.variant}"
        path = outdir / f"{tag}.json"
        if path.exists():
            print(f"[dryrun] {tag}: cached", flush=True)
            continue
        print(f"[dryrun] {tag}: lowering...", flush=True)
        try:
            rec = run_cell(arch, shape, args.mesh,
                           skip_collectives=args.skip_collectives, mesh=mesh,
                           overrides=overrides)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "status": "error", "error": str(e),
                   "traceback": traceback.format_exc()}
            failures += 1
        path.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] {tag}: {rec['status']} "
              f"(compile {rec.get('compile_s', '-')}s)", flush=True)
    print(f"[dryrun] done, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
