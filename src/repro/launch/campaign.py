"""CLI campaign driver — a whole parameter sweep, one dispatch per point.

  PYTHONPATH=src python -m repro.launch.campaign --workload wireless \\
      --seeds 8 --grid max_calls=4,8 --model-kw n_cells=64 \\
      --epochs 256 [--devices 2] [--route a2a] [--scheduler ltf] \\
      [--store campaign-results] [--require-drained]

Builds a :class:`repro.campaign.CampaignSpec` (seeds × the cartesian
``--grid`` product over ``--model-kw`` baselines), runs every grid point's
replications stacked through the engine's replication-vmapped fused drain —
two host dispatches per point regardless of the seed count — and writes one
JSON per point into the digest-keyed results store.  Re-running the same
command resumes: completed points are skipped.

Every choice-typed flag is driven by the live registries (the workload zoo
and the pipeline stage names), exactly like ``repro.launch.simulate`` —
:mod:`repro.testing.docs_check` cross-checks both CLIs.

Exit contract: nonzero if any replication's overflow/causality counters are
dirty (the clean-run contract), if any grid point is missing from the store
at the end, or — under ``--require-drained`` — if any point hit the
``--epochs`` bound with events still in flight.
"""
from __future__ import annotations

import argparse
import ast
import time

from .simulate import parse_kv


def parse_grid(pairs: list[str]) -> dict[str, list]:
    """``k=v1,v2,...`` strings → grid dict (python-literal values)."""
    out = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--grid expects k=v1,v2,..., got {pair!r}")
        k, vs = pair.split("=", 1)
        vals = []
        for v in vs.split(","):
            try:
                vals.append(ast.literal_eval(v))
            except (SyntaxError, ValueError):
                vals.append(v)
        if k in out:
            raise SystemExit(f"--grid axis {k!r} given twice")
        out[k] = vals
    return out


def main():
    from ..core.pipeline.names import (BATCH_IMPLS, PLACEMENTS, ROUTES,
                                       SELECTABLE_SCHEDULERS)
    from ..workloads.registry import all_workloads

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="wireless",
                    choices=all_workloads())
    ap.add_argument("--seeds", type=int, default=8,
                    help="replication count; seeds are seed-base..+N-1, all "
                         "stacked into ONE vmapped drain dispatch per point")
    ap.add_argument("--seed-base", type=int, default=0)
    ap.add_argument("--grid", action="append", default=[], metavar="K=V1,V2",
                    help="model-kwarg sweep axis (repeatable; points are the "
                         "cartesian product), e.g. --grid max_calls=4,8")
    ap.add_argument("--model-kw", action="append", default=[], metavar="K=V",
                    help="baseline workload make() override (repeatable)")
    ap.add_argument("--lookahead", type=float, default=0.5)
    ap.add_argument("--epoch-len", type=float, default=None)
    ap.add_argument("--epochs", type=int, default=256,
                    help="per-point fused-drain bound")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--scheduler", default="batch",
                    choices=list(SELECTABLE_SCHEDULERS))
    ap.add_argument("--route", default="allgather", choices=list(ROUTES))
    ap.add_argument("--batch-impl", default="rounds",
                    choices=list(BATCH_IMPLS))
    ap.add_argument("--pack-tile", type=int, default=64)
    ap.add_argument("--steal", action="store_true")
    ap.add_argument("--placement", default="equal", choices=list(PLACEMENTS))
    ap.add_argument("--rebalance-every", type=int, default=0)
    ap.add_argument("--migrate-cap", type=int, default=16)
    ap.add_argument("--placement-slack", type=float, default=2.0)
    ap.add_argument("--n-buckets", type=int, default=16)
    ap.add_argument("--bucket-cap", type=int, default=256)
    ap.add_argument("--route-cap", type=int, default=8192)
    ap.add_argument("--fallback-cap", type=int, default=8192)
    ap.add_argument("--store", default="campaign-results",
                    help="results-store root (one digest-keyed run dir per "
                         "spec; re-running resumes)")
    ap.add_argument("--require-drained", action="store_true",
                    help="fail if any grid point hits the --epochs bound "
                         "with events still in flight")
    args = ap.parse_args()

    from ..campaign import CampaignSpec, ResultsStore, run_campaign

    spec = CampaignSpec(
        workload=args.workload,
        seeds=tuple(range(args.seed_base, args.seed_base + args.seeds)),
        base_model_kw=dict(lookahead=args.lookahead,
                           **parse_kv(args.model_kw)),
        grid=parse_grid(args.grid),
        engine_kw=dict(
            lookahead=args.lookahead, epoch_len=args.epoch_len,
            n_buckets=args.n_buckets, bucket_cap=args.bucket_cap,
            route_cap=args.route_cap, fallback_cap=args.fallback_cap,
            scheduler=args.scheduler, route=args.route,
            batch_impl=args.batch_impl, pack_tile=args.pack_tile,
            steal=args.steal, steal_cap=4, claim_cap=8,
            placement=args.placement, rebalance_every=args.rebalance_every,
            migrate_cap=args.migrate_cap,
            placement_slack=args.placement_slack),
        devices=args.devices,
        max_epochs=args.epochs,
    )
    store = ResultsStore(args.store)
    print(f"[campaign] {args.workload}: {len(spec.points())} grid points × "
          f"{len(spec.seeds)} seeds → {store.run_dir(spec)}")

    t0 = time.perf_counter()
    summary = run_campaign(spec, store=store, log=print)
    dt = time.perf_counter() - t0

    done = sum(rep["processed"] for res in summary["results"]
               for rep in res["replications"])
    print(f"[campaign] {summary['ran']} points ran, {summary['resumed']} "
          f"resumed; {done} events total in {dt:.2f}s "
          f"({done / max(dt, 1e-9):,.0f} ev/s aggregate)")

    failed = False
    if summary["unclean"]:
        for index, seed, bad in summary["unclean"]:
            print(f"[campaign] UNCLEAN point {index} seed {seed}: {bad}")
        failed = True
    if summary["missing"]:
        print(f"[campaign] MISSING store entries for points "
              f"{summary['missing']}")
        failed = True
    if summary["undrained"]:
        print(f"[campaign] points {summary['undrained']} hit the "
              f"{args.epochs}-epoch bound with events in flight"
              + (" — failing (--require-drained)" if args.require_drained
                 else ""))
        failed = failed or args.require_drained
    if failed:
        raise SystemExit(1)
    print(f"[campaign] complete ✓ ({store.run_dir(spec)})")


if __name__ == "__main__":
    main()
