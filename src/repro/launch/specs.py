"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero device allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ShapeConfig
from ..configs.registry import FULL_ATTENTION_ONLY, get_config
from ..data.synthetic import batch_spec
from ..models.registry import build_model


def cell_is_skipped(arch: str, shape_name: str) -> str | None:
    """Returns a skip reason or None."""
    if shape_name == "long_500k" and arch in FULL_ATTENTION_ONLY:
        return ("pure full-attention arch: 524k-token quadratic prefill is "
                "not representable without sub-quadratic attention "
                "(DESIGN.md §Arch-applicability)")
    return None


def input_specs(arch: str, shape_name: str, overrides: dict | None = None):
    """Returns a dict describing what to lower for this cell:

    kind=train:   {params, opt_state, batch}
    kind=prefill: {params, batch, caches}
    kind=decode:  {params, tokens, caches, cur_len}

    overrides: ModelConfig field=value replacements (hillclimb variants);
    keys prefixed "train." are handled by the caller.
    """
    import dataclasses
    cfg = get_config(arch)
    model_over = {k: v for k, v in (overrides or {}).items()
                  if not k.startswith("train.") and not k.startswith("_")}
    if model_over:
        cfg = dataclasses.replace(cfg, **model_over)
    shape: ShapeConfig = SHAPES[shape_name]
    model = build_model(cfg)
    key_spec = jax.eval_shape(lambda: jax.random.key(0))
    params = jax.eval_shape(model.init, key_spec)

    if shape.kind == "train":
        from ..train import optimizer as opt
        batch = batch_spec(cfg, shape.global_batch, shape.seq_len)
        opt_state = jax.eval_shape(opt.init, params)
        return {"kind": "train", "cfg": cfg, "model": model, "params": params,
                "opt_state": opt_state, "batch": batch}

    if shape.kind == "prefill":
        caches = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        batch = batch_spec(cfg, shape.global_batch, shape.seq_len)
        return {"kind": "prefill", "cfg": cfg, "model": model,
                "params": params, "batch": batch, "caches": caches}

    # decode: one new token against a cache of seq_len
    caches = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    if cfg.frontend == "audio":
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
    else:
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return {"kind": "decode", "cfg": cfg, "model": model, "params": params,
            "tokens": tokens, "caches": caches}
