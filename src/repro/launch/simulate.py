"""CLI simulation driver (the paper-kind end-to-end entry point).

  PYTHONPATH=src python -m repro.launch.simulate --workload phold \\
      --epochs 100 [--devices 2] [--scheduler ltf] [--route a2a] \\
      [--batch-impl packed] [--placement adaptive --rebalance-every 4] \\
      [--steal] [--drain] [--model-kw n_channels=2] [--verify]

Every choice-typed flag is driven by the live registries — the workload zoo
(:mod:`repro.workloads.registry`) and the pipeline stage names
(:mod:`repro.core.pipeline.names`) — so a newly registered workload, batch
implementation or placement shows up here without touching this file
(:mod:`repro.testing.docs_check` cross-checks that this stays true).

Exit contract: any nonzero overflow/causality counter is a **failed run**
(events were dropped or misordered; the perf line printed above it is
meaningless) and the process exits nonzero via the shared
:func:`repro.testing.assert_clean` checker.

``--drain`` completes the whole simulation as one fused on-device dispatch
(:meth:`ParsirEngine.run_until_drained` bounded by ``--epochs``) instead of
a fixed horizon; ``--verify`` cross-checks the final object state bit-exactly
against the sequential oracle for any workload under ``--dist dyadic``.
"""
from __future__ import annotations

import argparse
import time


def parse_kv(pairs: list[str]) -> dict:
    """``k=v`` strings → kwargs dict (python-literal values, else str)."""
    import ast
    out = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--model-kw expects k=v, got {pair!r}")
        k, v = pair.split("=", 1)
        try:
            out[k] = ast.literal_eval(v)
        except (SyntaxError, ValueError):
            out[k] = v
    return out


def main():
    from ..core.pipeline.names import (BATCH_IMPLS, PLACEMENTS, ROUTES,
                                       SELECTABLE_SCHEDULERS)
    from ..workloads.registry import all_workloads

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="phold", choices=all_workloads())
    ap.add_argument("--objects", type=int, default=512)
    ap.add_argument("--lookahead", type=float, default=0.5)
    ap.add_argument("--epoch-len", type=float, default=None)
    ap.add_argument("--dist", default="exponential",
                    choices=["exponential", "uniform24", "dyadic"])
    ap.add_argument("--model-kw", action="append", default=[],
                    metavar="K=V", help="extra workload make() override "
                    "(repeatable), e.g. --model-kw max_calls=8")
    ap.add_argument("--epochs", type=int, default=100,
                    help="epochs to run (--drain: the drain bound)")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--scheduler", default="batch",
                    choices=list(SELECTABLE_SCHEDULERS))
    ap.add_argument("--route", default="allgather", choices=list(ROUTES))
    ap.add_argument("--batch-impl", default="rounds",
                    choices=list(BATCH_IMPLS))
    ap.add_argument("--pack-tile", type=int, default=64)
    ap.add_argument("--steal", action="store_true")
    ap.add_argument("--placement", default="equal", choices=list(PLACEMENTS))
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="adaptive placement: epochs between rebalances")
    ap.add_argument("--migrate-cap", type=int, default=16)
    ap.add_argument("--placement-slack", type=float, default=2.0)
    ap.add_argument("--opt-window", type=int, default=0,
                    help="speculate up to W epochs past the safe horizon "
                         "(Time Warp lite; 0 = strictly conservative). "
                         "Same bits either way — stragglers roll the window "
                         "back; see stats rollbacks/speculated/spec_commits")
    ap.add_argument("--opt-stage-cap", type=int, default=0,
                    help="staging buffer for speculative emissions "
                         "(0 = route_cap); overflow aborts the window, "
                         "never drops")
    ap.add_argument("--opt-commit", default="device",
                    choices=["device", "global"],
                    help="speculation commit locality: 'device' rolls back "
                         "only devices that received a straggler, 'global' "
                         "is the atomic all-or-nothing vote (same bits)")
    ap.add_argument("--opt-adaptive", action="store_true",
                    help="retune the live speculation window between drain "
                         "dispatches from the observed rollback rate "
                         "(--opt-window becomes the cap; --drain only)")
    ap.add_argument("--n-buckets", type=int, default=16)
    ap.add_argument("--bucket-cap", type=int, default=256)
    ap.add_argument("--route-cap", type=int, default=8192)
    ap.add_argument("--fallback-cap", type=int, default=8192)
    ap.add_argument("--drain", action="store_true",
                    help="run to empty as ONE fused on-device dispatch "
                         "(run_until_drained, bounded by --epochs)")
    ap.add_argument("--verify", action="store_true",
                    help="cross-check final object state against the "
                         "sequential oracle (dyadic dist only)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from jax.sharding import Mesh

    from ..core.engine import AXIS, EngineConfig, ParsirEngine
    from ..testing import assert_clean
    from ..workloads.registry import get_workload

    devs = jax.devices()
    if len(devs) < args.devices:
        raise SystemExit(
            f"{len(devs)} devices visible, need {args.devices} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{args.devices}")
    mesh = Mesh(np.array(devs[:args.devices]), (AXIS,))

    model = get_workload(args.workload, n_objects=args.objects,
                         lookahead=args.lookahead, dist=args.dist,
                         **parse_kv(args.model_kw))
    cfg = EngineConfig(
        lookahead=args.lookahead, epoch_len=args.epoch_len,
        n_buckets=args.n_buckets, bucket_cap=args.bucket_cap,
        route_cap=args.route_cap, fallback_cap=args.fallback_cap,
        scheduler=args.scheduler, route=args.route,
        batch_impl=args.batch_impl, pack_tile=args.pack_tile,
        steal=args.steal, steal_cap=4, claim_cap=8,
        placement=args.placement, rebalance_every=args.rebalance_every,
        migrate_cap=args.migrate_cap, placement_slack=args.placement_slack,
        opt_window=args.opt_window, opt_stage_cap=args.opt_stage_cap,
        opt_commit=args.opt_commit, opt_adaptive=args.opt_adaptive)
    eng = ParsirEngine(model, cfg, mesh=mesh)

    st = eng.init()
    # warm/compile the exact program the timed section dispatches, without
    # advancing the simulation: both loops no-op at a zero bound.
    st = (eng.run_until_drained(st, 0) if args.drain else eng.run(st, 0))
    base = eng.totals(st)["processed"]

    t0 = time.perf_counter()
    st = (eng.run_until_drained(st, args.epochs) if args.drain
          else eng.run(st, args.epochs))
    st.stats.processed.block_until_ready()
    dt = time.perf_counter() - t0

    tot = eng.totals(st)
    epochs_run = int(np.asarray(st.epoch)[0])
    done = tot["processed"] - base
    print(f"[simulate] {args.workload} D={args.devices}: {done} events over "
          f"{epochs_run} epochs in {dt:.2f}s ({done / max(dt, 1e-9):,.0f} "
          f"ev/s) — {eng.dispatches} host dispatches")
    if args.drain:
        left = eng.in_flight(st)
        print(f"[simulate] drain: {'complete' if left == 0 else 'BOUND HIT'} "
              f"at epoch {epochs_run} (in-flight {left})")
    print(f"[simulate] stats: {tot}")
    try:
        assert_clean(tot, context="simulate")
    except AssertionError as e:
        raise SystemExit(f"[simulate] {e}") from None

    if args.verify:
        if args.dist != "dyadic":
            raise SystemExit("--verify needs --dist dyadic (bit-exact mode)")
        from ..core.ref_engine import run_sequential
        ref = run_sequential(model, epochs_run, cfg.epoch_len)
        assert tot["processed"] == ref.total_processed, \
            (tot["processed"], ref.total_processed)
        gobj = eng.global_object_state(st)
        for key, leaf in gobj.items():
            ref_leaf = np.stack([s[key] for s in ref.obj_state])
            assert np.array_equal(leaf, ref_leaf), \
                f"object state {key!r} diverges from the oracle"
        print("[simulate] verified bit-exact vs sequential oracle ✓")


if __name__ == "__main__":
    main()
