"""CLI simulation driver (the paper-kind end-to-end entry point).

  PYTHONPATH=src python -m repro.launch.simulate --objects 1024 --initial 20 \
      --lookahead 0.5 --epochs 100 [--steal] [--route a2a] [--verify]
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=512)
    ap.add_argument("--initial", type=int, default=20)
    ap.add_argument("--state-nodes", type=int, default=512)
    ap.add_argument("--realloc", type=float, default=0.004)
    ap.add_argument("--lookahead", type=float, default=0.5)
    ap.add_argument("--epoch-len", type=float, default=None)
    ap.add_argument("--dist", default="exponential",
                    choices=["exponential", "uniform24", "dyadic"])
    ap.add_argument("--epochs", type=int, default=100)
    ap.add_argument("--scheduler", default="batch", choices=["batch", "ltf"])
    ap.add_argument("--route", default="allgather",
                    choices=["allgather", "a2a"])
    ap.add_argument("--steal", action="store_true")
    ap.add_argument("--batch-impl", default="rounds",
                    choices=["rounds", "model"])
    ap.add_argument("--verify", action="store_true",
                    help="cross-check against the sequential oracle "
                         "(dyadic dist only)")
    args = ap.parse_args()

    from ..core.engine import EngineConfig, ParsirEngine
    from ..phold.model import Phold, PholdParams

    model = Phold(PholdParams(
        n_objects=args.objects, initial_events=args.initial,
        state_nodes=args.state_nodes, realloc_fraction=args.realloc,
        lookahead=args.lookahead, dist=args.dist))
    cfg = EngineConfig(
        lookahead=args.lookahead, epoch_len=args.epoch_len, n_buckets=16,
        bucket_cap=max(64, 4 * args.initial), route_cap=8192,
        fallback_cap=8192, scheduler=args.scheduler, route=args.route,
        steal=args.steal, steal_cap=4, claim_cap=8,
        batch_impl=args.batch_impl)
    eng = ParsirEngine(model, cfg)

    st = eng.init()
    st = eng.run(st, 5)  # warm/compile
    base = eng.totals(st)["processed"]
    t0 = time.perf_counter()
    st = eng.run(st, args.epochs)
    st.stats.processed.block_until_ready()
    dt = time.perf_counter() - t0
    tot = eng.totals(st)
    print(f"[simulate] {tot['processed'] - base} events in {dt:.2f}s "
          f"({(tot['processed'] - base) / dt:,.0f} ev/s)")
    print(f"[simulate] stats: {tot}")
    bad = (tot["cal_overflow"] or tot["late_events"]
           or tot["lookahead_violations"] or tot["route_overflow"])
    if bad:
        raise SystemExit("[simulate] CAPACITY/CAUSALITY VIOLATION — resize "
                         "bucket/route/fallback caps")

    if args.verify:
        if args.dist != "dyadic":
            raise SystemExit("--verify needs --dist dyadic (bit-exact mode)")
        from ..core.ref_engine import run_sequential
        import numpy as np
        ref = run_sequential(model, args.epochs + 5, cfg.epoch_len)
        assert tot["processed"] == ref.total_processed
        pay = np.asarray(st.obj["payload"])
        ref_pay = np.stack([s["payload"] for s in ref.obj_state])
        assert np.array_equal(pay, ref_pay)
        print("[simulate] verified bit-exact vs sequential oracle ✓")


if __name__ == "__main__":
    main()
