"""CLI serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --batch 4 --prompt-len 32 --tokens 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np
    from ..configs.registry import get_config
    from ..data.synthetic import make_batch
    from ..models.registry import build_model
    from ..serve.engine import ServeSession

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, args.batch, args.prompt_len)

    sess = ServeSession(model, params, args.batch,
                        max_len=args.prompt_len + args.tokens + 1,
                        dtype=np.float32 if args.reduced else None)
    t0 = time.perf_counter()
    first = sess.prefill(batch)
    t1 = time.perf_counter()
    out = sess.decode(first, args.tokens - 1)
    t2 = time.perf_counter()
    total = args.batch * (args.tokens - 1)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill={1e3*(t1-t0):.0f}ms decode={1e3*(t2-t1):.0f}ms "
          f"({total/(t2-t1):,.0f} tok/s incl. compile)")
    for b in range(min(args.batch, 4)):
        print(f"[serve] req{b}: {[int(first[b])] + out[b].tolist()}")


if __name__ == "__main__":
    main()
