"""Production mesh construction.

Never touches jax device state at import time — everything is behind
functions (the dry-run sets XLA_FLAGS before first jax init; tests keep their
single CPU device).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where this jax version has them
    (jax <= 0.4.x predates jax.sharding.AxisType; default semantics match)."""
    at = getattr(jax.sharding, "AxisType", None)
    kw = {"axis_types": (at.Auto,) * len(axes)} if at is not None else {}
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e pod slice); 2 pods for the multi-pod run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(n_devices: int | None = None, axis: str = "data"):
    """Small mesh over whatever devices exist (tests, examples)."""
    devs = jax.devices()[:n_devices] if n_devices else jax.devices()
    return make_mesh((len(devs),), (axis,))
