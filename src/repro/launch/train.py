"""CLI training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    from ..configs.base import TrainConfig
    from ..configs.registry import get_config
    from ..data.synthetic import SyntheticLoader
    from ..models.registry import build_model
    from ..train.loop import Trainer

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       microbatch=args.microbatch,
                       checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.ckpt_every)
    loader = SyntheticLoader(cfg, args.batch, args.seq)
    tr = Trainer(model, tcfg, loader=loader)
    params, opt_state, hist = tr.run(args.steps)
    print(f"[train] done: first loss {hist[0]['loss']:.4f} "
          f"final loss {hist[-1]['loss']:.4f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
