"""Mixture-of-Experts layers (Kimi-K2 / DeepSeek-V2 style) and MLA attention.

MoE dispatch is the production sort-based capacity scheme (not the dense
one-hot einsum, which cannot scale to 384 experts x 1M tokens):

  top-k -> flatten (token, slot) -> sort by expert -> rank-in-group (prefix
  sums — the same lock-free slot assignment idea as the PDES calendar insert,
  see core/calendar.py) -> capacity-capped scatter into an [E, cap, d] expert
  buffer -> batched expert matmuls -> weighted combine-scatter back.

Sharding: the expert buffer carries a sharding constraint P("expert"-ish on
the E axis, "data" on the capacity axis); under pjit/GSPMD the token->expert
scatter then lowers to the expert-parallel all-to-all.

MLA (DeepSeek): KV is compressed to a kv_lora_rank latent + a shared RoPE key.
Prefill expands the latent to per-head K/V (cheap at T==S); decode uses the
*absorbed* form — scores and context are computed entirely in latent space, so
the cache is [S, r + rope_dim] instead of [S, H, 2*hd] (the paper's ~10x KV
saving, and the reason decode_32k x batch 128 fits).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, dt_of, rope


# -- MoE FFN -------------------------------------------------------------------

def init_moe(cfg, key):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.02),
        "wg": dense_init(ks[1], (E, d, ff)),
        "wu": dense_init(ks[2], (E, d, ff)),
        "wd": dense_init(ks[3], (E, ff, d), scale=1.0 / math.sqrt(ff)),
    }
    if cfg.n_shared_experts:
        sf = cfg.moe_d_ff * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {"wg": dense_init(kss[0], (d, sf)),
                       "wu": dense_init(kss[1], (d, sf)),
                       "wd": dense_init(kss[2], (sf, d),
                                        scale=1.0 / math.sqrt(sf))}
    return p


def _group_ranks(key, n_groups):
    order = jnp.argsort(key, stable=True)
    ks = key[order]
    idx = jnp.arange(key.shape[0], dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    return order, ks, idx - start


def moe_ffn(cfg, p, x, mesh_axes=("model", "data")):
    """x: [B, T, d] -> [B, T, d] via top-k routed experts + shared experts."""
    from jax.sharding import PartitionSpec as P
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    cdt = dt_of(cfg)
    Tt = B * T
    xf = x.reshape(Tt, d)

    logits = (xf @ p["router"].astype(cdt)).astype(jnp.float32)   # [Tt, E]
    gate, idx = jax.lax.top_k(logits, k)                          # [Tt, k]
    gate = jax.nn.softmax(gate, axis=-1).astype(cdt)

    slot_expert = idx.reshape(-1).astype(jnp.int32)               # [Tt*k]
    slot_token = jnp.repeat(jnp.arange(Tt, dtype=jnp.int32), k)
    slot_gate = gate.reshape(-1)

    cap = max(128, int(math.ceil(cfg.capacity_factor * Tt * k / E / 128)) * 128)
    order, ks_sorted, rank = _group_ranks(slot_expert, E)
    keep = rank < cap
    pos = jnp.where(keep, ks_sorted * cap + rank, E * cap)

    buf = jnp.zeros((E * cap, d), cdt).at[pos].set(
        xf[slot_token[order]], mode="drop").reshape(E, cap, d)
    from ..distributed.sharding import maybe_constraint
    layout = getattr(cfg, "moe_buf_layout", "md")
    if layout == "md":
        buf = maybe_constraint(buf, "model", "data", None)
    elif layout == "m":
        buf = maybe_constraint(buf, "model", None, None)
    # "none": let GSPMD propagate freely

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(cdt))) \
        * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(cdt))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(cdt))

    contrib = out_buf.reshape(E * cap, d)[jnp.clip(pos, 0, E * cap - 1)]
    contrib = contrib * (slot_gate[order] * keep.astype(cdt))[:, None]
    y = jnp.zeros((Tt, d), cdt).at[slot_token[order]].add(contrib)

    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(xf @ sp["wg"].astype(cdt)) * (xf @ sp["wu"].astype(cdt))
        y = y + hs @ sp["wd"].astype(cdt)
    return y.reshape(B, T, d)


def aux_load_balance_loss(cfg, router_logits):
    """Switch-style load-balance auxiliary (per layer, averaged by caller)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    E = probs.shape[-1]
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * imp)


# -- MLA attention -----------------------------------------------------------------

def init_mla(cfg, key):
    d, Hq, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, Hq * (hd + rd))),
        "wdkv": dense_init(ks[1], (d, r)),
        "wkr": dense_init(ks[2], (d, rd)),
        "wukv": dense_init(ks[3], (r, Hq * 2 * hd)),
        "wo": dense_init(ks[4], (Hq * hd, d), scale=1.0 / math.sqrt(Hq * hd)),
    }


def mla_attention(cfg, p, x, positions, cache=None, cur_len=None):
    B, T, d = x.shape
    Hq, hd = cfg.n_heads, cfg.hd
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    cdt = dt_of(cfg)
    scale = 1.0 / math.sqrt(hd + rd)

    q = (x @ p["wq"].astype(cdt)).reshape(B, T, Hq, hd + rd)
    qn, qr = q[..., :hd], rope(q[..., hd:], positions, cfg.rope_theta)
    ckv = x @ p["wdkv"].astype(cdt)                                # [B,T,r]
    kr = rope((x @ p["wkr"].astype(cdt))[:, :, None, :], positions,
              cfg.rope_theta)[:, :, 0, :]                          # [B,T,rd]

    wukv = p["wukv"].astype(cdt).reshape(r, Hq, 2 * hd)
    wuk, wuv = wukv[..., :hd], wukv[..., hd:]

    if cache is None:
        # prefill/train: expand latent to per-head K/V, chunked causal attn.
        kn = jnp.einsum("btr,rhd->bthd", ckv, wuk)
        v = jnp.einsum("btr,rhd->bthd", ckv, wuv)
        kfull = jnp.concatenate(
            [kn, jnp.broadcast_to(kr[:, :, None, :], (B, T, Hq, rd))], axis=-1)
        qfull = jnp.concatenate([qn, qr], axis=-1)
        from .layers import _attn_chunked
        o = _attn_chunked(qfull, kfull, v, causal=True, q_offset=0,
                          chunk=min(1024, T))
        # note: _attn_chunked rescales by 1/sqrt(hd+rd) internally via hd of
        # its q — which is (hd+rd) here, matching `scale`.
        new_cache = None
    else:
        cckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(
            cache["ckv"].dtype), (0, cur_len, 0))
        ckr = jax.lax.dynamic_update_slice(cache["kr"], kr.astype(
            cache["kr"].dtype), (0, cur_len, 0))
        S = cckv.shape[1]
        # absorbed decode: all in latent space.
        q_abs = jnp.einsum("bthd,rhd->bthr", qn, wuk)              # [B,T,H,r]
        s = (jnp.einsum("bthr,bsr->bths", q_abs, cckv.astype(cdt))
             + jnp.einsum("bthp,bsp->bths", qr, ckr.astype(cdt))) * scale
        cols = jnp.arange(S, dtype=jnp.int32)
        s = jnp.where((cols < cur_len + T)[None, None, None, :], s, -1e30)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(cdt)
        ctx = jnp.einsum("bths,bsr->bthr", w, cckv.astype(cdt))
        o = jnp.einsum("bthr,rhd->bthd", ctx, wuv)
        new_cache = {"ckv": cckv, "kr": ckr}

    o = o.reshape(B, T, Hq * hd)
    return o @ p["wo"].astype(cdt), new_cache
