"""Shared neural building blocks (pure JAX, shardable, eval_shape-safe).

Conventions:
  * params are plain dicts of jnp arrays; init fns take (cfg, key);
  * activations run in cfg.dtype (bf16 by default), params kept f32;
  * attention is *chunked* (online softmax over KV blocks via lax.scan) for
    long sequences — scores are never materialized at [T, T], which is what
    makes the 32k-prefill and 500k-decode shapes compilable at all.  The
    Pallas flash kernel (kernels/flash_attention.py) is the TPU-optimized
    realization of the same schedule (cfg.attn_impl = "pallas").
  * decode paths take a KV cache with a traced ``cur_len`` and update in place
    (arena-style static allocation — no dynamic shapes anywhere).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp


def dt_of(cfg):
    return jnp.dtype(cfg.dtype)


def wp(p, name, cdt):
    """Fetch a weight in compute dtype, fsdp-gathered at use site."""
    from ..distributed.sharding import use_param
    return use_param(p[name].astype(cdt))


def cast_params(cfg, params):
    """Cast float leaves to cfg.param_dtype (bf16 master weights for the
    1T-scale configs; f32 default)."""
    pd = jnp.dtype(cfg.param_dtype)
    def cast(l):
        return l.astype(pd) if jnp.issubdtype(l.dtype, jnp.floating) else l
    return jax.tree.map(cast, params)


# -- init helpers --------------------------------------------------------------

def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale


# -- norms ----------------------------------------------------------------------

def init_norm(d: int, kind: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm(p, x, kind: str, eps: float):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# -- rotary ----------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., T, H, hd]; positions: [..., T] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# -- chunked (flash-style) attention ---------------------------------------------

def _attn_chunked(q, k, v, *, causal: bool, q_offset, chunk: int = 1024,
                  compute_dtype=jnp.float32):
    """q: [B,T,Hq,hd]; k,v: [B,S,Hkv,hd].  Online softmax over KV chunks.

    q_offset: absolute position of q[0] (decode: cur_len; train: 0).
    Memory: O(B*T*Hq*chunk) per step instead of O(B*T*Hq*S).
    """
    B, T, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]               # may differ from hd (e.g. MLA)
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    nc = max(1, S // chunk)
    assert S % nc == 0
    ck = S // nc

    qf = q.astype(compute_dtype).reshape(B, T, Hkv, G, hd)
    kc = k.astype(compute_dtype).reshape(B, nc, ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.astype(compute_dtype).reshape(B, nc, ck, Hkv, hdv).transpose(1, 0, 2, 3, 4)

    rows = q_offset + jnp.arange(T, dtype=jnp.int32)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        s = jnp.einsum("bthgd,bchd->bthgc", qf, kb,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            cols = ci * ck + jnp.arange(ck, dtype=jnp.int32)
            mask = rows[:, None] >= cols[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bthgc,bchd->bthgd", p.astype(compute_dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, T, Hkv, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, T, Hkv, G, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc, vc, jnp.arange(nc, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, T, Hq, hdv).astype(q.dtype)


def sdpa(cfg, q, k, v, *, causal: bool, q_offset=0):
    """Dispatch attention impl.  q: [B,T,Hq,hd]; k,v: [B,S,Hkv,hd]."""
    S = k.shape[1]
    if cfg.attn_impl == "pallas":
        from ..kernels import ops
        o = ops.mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), causal=causal)
        return o.transpose(0, 2, 1, 3)
    cdt = jnp.float32 if getattr(cfg, "attn_f32", True) else dt_of(cfg)
    base = getattr(cfg, "attn_chunk", 1024)
    if S <= 2 * base:
        # small-S direct path
        return _attn_chunked(q, k, v, causal=causal, q_offset=q_offset,
                             chunk=S, compute_dtype=cdt)
    chunk = base if S % base == 0 else 512 if S % 512 == 0 else S
    return _attn_chunked(q, k, v, causal=causal, q_offset=q_offset,
                         chunk=chunk, compute_dtype=cdt)


# -- GQA attention block ----------------------------------------------------------

def init_attn(cfg, key):
    d, hd, Hq, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, Hq * hd)),
        "wk": dense_init(ks[1], (d, Hkv * hd)),
        "wv": dense_init(ks[2], (d, Hkv * hd)),
        "wo": dense_init(ks[3], (Hq * hd, d), scale=1.0 / math.sqrt(Hq * hd)),
    }


def attention(cfg, p, x, positions, cache=None, cur_len=None):
    """x: [B,T,d].  cache: {"k","v": [B,Smax,Hkv,hd]} or None.

    Train/prefill: cache None (or filled and returned).  Decode: T is the new
    token count (usually 1); cache holds cur_len valid entries."""
    B, T, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    cdt = dt_of(cfg)
    q = (x @ wp(p, "wq", cdt)).reshape(B, T, Hq, hd)
    k = (x @ wp(p, "wk", cdt)).reshape(B, T, Hkv, hd)
    v = (x @ wp(p, "wv", cdt)).reshape(B, T, Hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        o = sdpa(cfg, q, k, v, causal=True)
        new_cache = None
    else:
        ck, cv = cache["k"], cache["v"]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cur_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cur_len, 0, 0))
        Smax = ck.shape[1]
        # mask out slots beyond cur_len+T via position-aware causal mask
        if getattr(cfg, "decode_attn", "gather") == "sp":
            o = _attn_decode_sp(cfg, q, ck.astype(cdt), cv.astype(cdt),
                                cur_len + T)
        else:
            o = _attn_masked_decode(q, ck.astype(cdt), cv.astype(cdt),
                                    cur_len + T)
        new_cache = {"k": ck, "v": cv}
    o = o.reshape(B, T, Hq * hd)
    return o @ wp(p, "wo", cdt), new_cache


def _attn_masked_decode(q, k, v, valid_len):
    """Decode attention: q [B,T,Hq,hd] over cache k/v [B,Smax,Hkv,hd], only
    the first valid_len cache slots participate (chunked over S)."""
    B, T, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    chunk = 1024 if S % 1024 == 0 else S
    nc = S // chunk
    qf = q.astype(jnp.float32).reshape(B, T, Hkv, G, hd)
    kc = k.astype(jnp.float32).reshape(B, nc, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.astype(jnp.float32).reshape(B, nc, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        s = jnp.einsum("bthgd,bchd->bthgc", qf, kb) * scale
        cols = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        s = jnp.where((cols < valid_len)[None, None, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p_, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bthgc,bchd->bthgd", p_, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, T, Hkv, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, T, Hkv, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, T, Hq, hd).astype(q.dtype)


# -- MLP ---------------------------------------------------------------------------

def init_mlp(cfg, key, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {"wg": dense_init(ks[0], (d, ff)),
                "wu": dense_init(ks[1], (d, ff)),
                "wd": dense_init(ks[2], (ff, d), scale=1.0 / math.sqrt(ff))}
    return {"wu": dense_init(ks[0], (d, ff)),
            "wd": dense_init(ks[1], (ff, d), scale=1.0 / math.sqrt(ff))}


def mlp(cfg, p, x):
    cdt = dt_of(cfg)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ wp(p, "wg", cdt)) * (x @ wp(p, "wu", cdt))
    else:
        h = jax.nn.gelu(x @ wp(p, "wu", cdt))
    return h @ wp(p, "wd", cdt)


# -- embeddings ---------------------------------------------------------------------

def init_embed(cfg, key):
    e = {"tok": dense_init(key, (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        e["head"] = dense_init(jax.random.fold_in(key, 1),
                               (cfg.d_model, cfg.vocab_size))
    return e


def embed(cfg, p, tokens):
    return p["tok"].astype(dt_of(cfg))[tokens]


def unembed(cfg, p, x):
    from ..distributed.sharding import use_param
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    out = x @ use_param(w.astype(dt_of(cfg)))
    return out.astype(jnp.float32) if getattr(cfg, "logits_fp32", True) else out


def _attn_decode_sp(cfg, q, k, v, valid_len):
    """Flash-decoding: attention over a sequence-sharded KV cache without
    gathering it.  Each model-axis shard computes partial (m, l, acc) over its
    local cache slice; the partials merge with a log-sum-exp psum — the cache
    never moves, only [B,T,H]-sized stats do.  Falls back to the gather path
    when no mesh/axis applies."""
    from ..distributed.sharding import ambient_mesh
    from jax.sharding import PartitionSpec as P
    mesh = ambient_mesh()
    B, T, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    if (mesh is None or "model" not in mesh.axis_names
            or S % mesh.shape["model"] != 0):
        return _attn_masked_decode(q, k, v, valid_len)
    import numpy as np
    bnames = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = int(np.prod([mesh.shape[n] for n in bnames])) if bnames else 1
    bspec = bnames if (bnames and B % bsize == 0) else None
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    S_loc = S // mesh.shape["model"]

    def local(qb, kb, vb, vlen):
        base = jax.lax.axis_index("model") * S_loc
        qf = qb.astype(jnp.float32).reshape(qb.shape[0], T, Hkv, G, hd)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        s = jnp.einsum("bthgd,bshd->bthgs", qf, kf) * scale
        cols = base + jnp.arange(S_loc, dtype=jnp.int32)
        s = jnp.where((cols < vlen)[None, None, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bthgs,bshd->bthgd", p, vf)
        # merge partials across the sequence shards
        M = jax.lax.pmax(m, "model")
        w = jnp.exp(m - M)
        l_g = jax.lax.psum(l * w, "model")
        acc_g = jax.lax.psum(acc * w[..., None], "model")
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(qb.shape[0], T, Hq, hd).astype(qb.dtype)

    from ..core.engine import _shard_map  # reuse the version-compat wrapper
    fn = _shard_map(
        local, mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, "model", None, None),
                  P(bspec, "model", None, None), P()),
        out_specs=P(bspec, None, None, None))
    return fn(q, k, v, valid_len)
