"""Zamba2 hybrid (arXiv:2411.15242): Mamba-2 backbone + one weight-SHARED
attention block invoked every ``attn_every`` layers on concat(hidden, embed0).

The shared block attends at width 2*d_model (32 heads x 128 = 4096 for the
1.2B config) and projects back to d_model.  Each *invocation* gets its own KV
cache slot (weights shared, caches not) — allocated per invocation, not per
layer, so the long-context cache is ~6x smaller than a naive per-layer layout.
LoRA-per-invocation adapters from the paper are omitted (noted in DESIGN.md).
Layers are a python loop (heterogeneous structure; 38 small blocks keep the
HLO manageable without scan).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import (dense_init, dt_of, embed, init_embed, init_norm, norm,
                     rope, sdpa, unembed, _attn_masked_decode)
from .mamba2 import init_mamba_block, mamba_apply


def init_shared_attn(cfg, key):
    da = 2 * cfg.d_model
    hd = cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 7)
    return {
        "ln1": init_norm(da, cfg.norm),
        "wq": dense_init(ks[0], (da, Hq * hd)),
        "wk": dense_init(ks[1], (da, Hkv * hd)),
        "wv": dense_init(ks[2], (da, Hkv * hd)),
        "wo": dense_init(ks[3], (Hq * hd, da), scale=1.0 / math.sqrt(Hq * hd)),
        "ln2": init_norm(da, cfg.norm),
        "wg": dense_init(ks[4], (da, cfg.d_ff)),
        "wu": dense_init(ks[5], (da, cfg.d_ff)),
        "wd": dense_init(ks[6], (cfg.d_ff, da), scale=1.0 / math.sqrt(cfg.d_ff)),
        "wproj": dense_init(jax.random.fold_in(key, 9), (da, cfg.d_model),
                            scale=1.0 / math.sqrt(da)),
    }


def shared_attn_apply(cfg, p, h, e0, positions, cache=None, cur_len=None):
    """h: hidden [B,T,d]; e0: initial embeddings [B,T,d]."""
    B, T, d = h.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    cdt = dt_of(cfg)
    xa = jnp.concatenate([h, e0], axis=-1)               # [B,T,2d]
    y = norm(p["ln1"], xa, cfg.norm, cfg.norm_eps)
    q = (y @ p["wq"].astype(cdt)).reshape(B, T, Hq, hd)
    k = (y @ p["wk"].astype(cdt)).reshape(B, T, Hkv, hd)
    v = (y @ p["wv"].astype(cdt)).reshape(B, T, Hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        o = sdpa(cfg, q, k, v, causal=True)
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cur_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cur_len, 0, 0))
        if getattr(cfg, "decode_attn", "gather") == "sp":
            from .layers import _attn_decode_sp
            o = _attn_decode_sp(cfg, q, ck.astype(cdt), cv.astype(cdt),
                                cur_len + T)
        else:
            o = _attn_masked_decode(q, ck.astype(cdt), cv.astype(cdt),
                                    cur_len + T)
        new_cache = {"k": ck, "v": cv}
    xa = xa + (o.reshape(B, T, Hq * hd) @ p["wo"].astype(cdt))
    y = norm(p["ln2"], xa, cfg.norm, cfg.norm_eps)
    ff = jax.nn.silu(y @ p["wg"].astype(cdt)) * (y @ p["wu"].astype(cdt))
    xa = xa + ff @ p["wd"].astype(cdt)
    return h + xa @ p["wproj"].astype(cdt), new_cache


class Zamba:
    def __init__(self, cfg):
        self.cfg = cfg
        every = cfg.attn_every or 6
        self.attn_at = [i for i in range(cfg.n_layers) if i % every == 0]

    def init(self, key):
        cfg = self.cfg
        params = {"embed": init_embed(cfg, key),
                  "final_norm": init_norm(cfg.d_model, cfg.norm),
                  "shared_attn": init_shared_attn(cfg, jax.random.fold_in(key, 3))}
        keys = jax.random.split(jax.random.fold_in(key, 5), cfg.n_layers)
        params["blocks"] = [init_mamba_block(cfg, k) for k in keys]
        from .layers import cast_params
        return cast_params(cfg, params)

    def _run(self, params, x, positions, mamba_states, attn_caches, cur_len,
             decode):
        cfg = self.cfg
        e0 = x
        new_m, new_a = [], []
        inv = 0
        for i, bp in enumerate(params["blocks"]):
            if i in self.attn_at:
                cache = None if attn_caches is None else attn_caches[inv]
                x, nc = shared_attn_apply(cfg, params["shared_attn"], x, e0,
                                          positions, cache, cur_len)
                new_a.append(nc)
                inv += 1
            st = None if mamba_states is None else mamba_states[i]
            x, ns = mamba_apply(cfg, bp, x, st, decode)
            new_m.append(ns)
        x = norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        return x, new_m, new_a

    def loss(self, params, batch):
        cfg = self.cfg
        x = embed(cfg, params["embed"], batch["tokens"])
        T = x.shape[1]
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]
        x, _, _ = self._run(params, x, positions, None, None, None, False)
        logits = unembed(cfg, params["embed"], x)
        lp = jax.nn.log_softmax(logits, axis=-1)
        tgt = batch["tokens"][:, 1:]
        sel = jnp.take_along_axis(lp[:, :-1], tgt[..., None], axis=-1)[..., 0]
        return -jnp.mean(sel)

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        B = batch_size
        W, C = cfg.ssm_conv, cfg.d_inner + 2 * cfg.ssm_state
        H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        mamba = [{"conv": jnp.zeros((B, W - 1, C), dtype),
                  "h": jnp.zeros((B, H, N, P), jnp.float32)}
                 for _ in range(cfg.n_layers)]
        attn = [{"k": jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), dtype),
                 "v": jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), dtype)}
                for _ in self.attn_at]
        return {"mamba": mamba, "attn": attn}

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        x = embed(cfg, params["embed"], batch["tokens"])
        T = x.shape[1]
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]
        x, new_m, new_a = self._run(params, x, positions, caches["mamba"],
                                    caches["attn"], jnp.int32(0), False)
        logits = unembed(cfg, params["embed"], x[:, -1:])
        return logits, {"mamba": new_m, "attn": new_a}

    def decode_step(self, params, tokens, caches, cur_len):
        cfg = self.cfg
        x = embed(cfg, params["embed"], tokens)
        positions = cur_len + jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, new_m, new_a = self._run(params, x, positions, caches["mamba"],
                                    caches["attn"], cur_len, True)
        logits = unembed(cfg, params["embed"], x)
        return logits, {"mamba": new_m, "attn": new_a}
