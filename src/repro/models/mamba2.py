"""Mamba-2 block (SSD) — used by the zamba2 hybrid.

Train/prefill use the chunkwise-parallel SSD form (kernels/ssd_scan.py on TPU,
pure-jnp mirror here for the dry-run path); decode uses the O(1) per-step
recurrence.  State = (conv window, SSM state h [B,H,N,P]) — constant in
sequence length, which is what qualifies the hybrid for long_500k.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..kernels import ops
from .layers import dense_init, dt_of, init_norm, norm


def init_mamba_block(cfg, key):
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * N
    return {
        "ln": init_norm(d, cfg.norm),
        "win": dense_init(ks[0], (d, 2 * di + 2 * N + H)),
        "conv": dense_init(ks[1], (cfg.ssm_conv, conv_ch), scale=0.5),
        "a_log": jnp.zeros((H,), jnp.float32),          # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "dskip": jnp.ones((H,), jnp.float32),
        "out_norm": init_norm(di, "rms"),
        "wout": dense_init(ks[2], (di, d), scale=1.0 / math.sqrt(di)),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv over time.  x: [B,T,C]; w: [W,C].

    state: [B, W-1, C] previous inputs (decode) or None (train, zero-pad).
    Returns (y [B,T,C], new_state [B, W-1, C])."""
    B, T, C = x.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # [B, T+W-1, C]
    y = sum(xp[:, i:i + T, :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y, new_state


def ssd_final_state(x, dt, A, B):
    """SSM state after a full sequence: h_T = Σ_j exp(Σ_{k>j} A·dt_k) dt_j B_j x_j^T.

    x: [b,T,H,P]; dt: [b,T,H]; A: [H]; B: [b,T,N] → h [b,H,N,P]."""
    l = jnp.cumsum(A[None, None, :] * dt, axis=1)        # [b,T,H] inclusive
    w = jnp.exp(l[:, -1:, :] - l) * dt                   # [b,T,H]
    return jnp.einsum("bth,btn,bthp->bhnp", w.astype(jnp.float32),
                      B.astype(jnp.float32), x.astype(jnp.float32))


def mamba_apply(cfg, p, x, state=None, decode=False):
    """x: [B,T,d].  state: {"conv": [B,W-1,C], "h": [B,H,N,P]} or None."""
    B, T, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cdt = dt_of(cfg)
    hloc = norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    proj = hloc @ p["win"].astype(cdt)
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt_raw = proj[..., -H:]

    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv"].astype(cdt), conv_state)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :di].reshape(B, T, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])

    if decode:
        h = state["h"]
        decay = jnp.exp(A[None, :] * dt[:, 0])                     # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0],
                         Bm[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h)
        y = y[:, None].astype(cdt)                                 # [B,1,H,P]
        new_h = h
    else:
        use_pallas = cfg.attn_impl == "pallas"
        y = ops.ssd(xs, dt.astype(jnp.float32), A, Bm.astype(jnp.float32),
                    Cm.astype(jnp.float32), chunk=cfg.ssd_chunk,
                    use_pallas=use_pallas).astype(cdt)
        new_h = ssd_final_state(xs, dt, A, Bm) if state is not None else None

    y = y + xs.astype(cdt) * p["dskip"].astype(cdt)[None, None, :, None]
    y = y.reshape(B, T, di)
    y = norm(p["out_norm"], y, "rms", cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = x + y @ p["wout"].astype(cdt)
    new_state = None if state is None and not decode else \
        {"conv": new_conv, "h": new_h}
    return out, new_state
