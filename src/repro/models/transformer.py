"""Decoder-only LM covering the dense and MoE families (granite, stablelm,
starcoder2, llama3.2, musicgen/internvl2 backbones, kimi-k2, deepseek-v2).

Layers are stacked and iterated with ``lax.scan`` (one traced block instead of
n_layers copies — keeps dry-run HLO size and compile time sane at 61 layers)
with configurable remat.  Logits are computed vocab-sharded (constraint
applied in train/step.py) so the [B, S, V] tensor never materializes
unsharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import moe as moe_mod
from .layers import (attention, dt_of, embed, init_attn, init_embed, init_mlp,
                     init_norm, mlp, norm, unembed)
from .moe import init_mla, init_moe, mla_attention, moe_ffn


def init_block(cfg, key):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    b = {"ln1": init_norm(d, cfg.norm), "ln2": init_norm(d, cfg.norm)}
    b["attn"] = init_mla(cfg, ks[0]) if cfg.use_mla else init_attn(cfg, ks[0])
    if cfg.n_experts:
        b["moe"] = init_moe(cfg, ks[1])
    else:
        b["mlp"] = init_mlp(cfg, ks[1])
    return b


def block_apply(cfg, bp, x, positions, cache=None, cur_len=None):
    attn_fn = mla_attention if cfg.use_mla else attention
    h, new_cache = attn_fn(cfg, bp["attn"], norm(bp["ln1"], x, cfg.norm,
                                                 cfg.norm_eps),
                           positions, cache, cur_len)
    x = x + h
    inner = norm(bp["ln2"], x, cfg.norm, cfg.norm_eps)
    if cfg.n_experts:
        ff = moe_ffn(cfg, bp["moe"], inner)
    else:
        ff = mlp(cfg, bp["mlp"], inner)
    return x + ff, new_cache


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


class DecoderLM:
    """Functional model object (init / train loss / prefill / decode)."""

    def __init__(self, cfg):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------

    def init(self, key):
        cfg = self.cfg
        kemb, kblocks, kfin, kfe = jax.random.split(key, 4)
        params = {"embed": init_embed(cfg, kemb),
                  "final_norm": init_norm(cfg.d_model, cfg.norm)}
        keys = jax.random.split(kblocks, cfg.n_layers)
        if cfg.scan_layers:
            params["blocks"] = jax.vmap(lambda k: init_block(cfg, k))(keys)
        else:
            params["blocks"] = [init_block(cfg, k) for k in keys]
        if cfg.frontend == "vision":
            params["patch_proj"] = jax.random.normal(
                kfe, (cfg.d_model, cfg.d_model), jnp.float32) * 0.02
        from .layers import cast_params
        return cast_params(cfg, params)

    # -- input embedding (incl. frontend stubs) --------------------------------

    def embed_inputs(self, params, batch):
        """Returns (x [B,T,d], labels [B,T] or None, loss_mask [B,T])."""
        cfg = self.cfg
        cdt = dt_of(cfg)
        if cfg.frontend == "audio":
            # modality stub: precomputed EnCodec frame embeddings.
            x = batch["embeds"].astype(cdt)
            labels = batch.get("labels")
            mask = jnp.ones(x.shape[:2], bool)
        elif cfg.frontend == "vision":
            pe = batch["patch_embeds"].astype(cdt) @ params["patch_proj"].astype(cdt)
            te = embed(cfg, params["embed"], batch["tokens"])
            x = jnp.concatenate([pe, te], axis=1)
            P = pe.shape[1]
            labels = None
            if "tokens" in batch:
                pad = jnp.zeros((x.shape[0], P), jnp.int32)
                labels = jnp.concatenate([pad, batch["tokens"]], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((x.shape[0], P), bool),
                 jnp.ones(batch["tokens"].shape, bool)], axis=1)
        else:
            x = embed(cfg, params["embed"], batch["tokens"])
            labels = batch["tokens"]
            mask = jnp.ones(x.shape[:2], bool)
        return x, labels, mask

    # -- forward --------------------------------------------------------------

    def backbone(self, params, x, positions, caches=None, cur_len=None):
        cfg = self.cfg
        if cfg.scan_layers:
            def body(carry, layer_in):
                bp, cache_l = layer_in
                y, new_cache = block_apply(cfg, bp, carry, positions, cache_l,
                                           cur_len)
                return y, new_cache
            body = _maybe_remat(cfg, body)
            x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
        else:
            new_caches = []
            for i, bp in enumerate(params["blocks"]):
                c = None if caches is None else caches[i]
                x, nc = block_apply(cfg, bp, x, positions, c, cur_len)
                new_caches.append(nc)
        x = norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        return x, new_caches

    def loss(self, params, batch):
        """Next-token CE (mean over mask), for train_step."""
        cfg = self.cfg
        x, labels, mask = self.embed_inputs(params, batch)
        B, T, _ = x.shape
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]
        caches = None if cfg.scan_layers else None
        x, _ = self.backbone(params, x, positions,
                             caches=_none_caches(cfg) if cfg.scan_layers else None)
        logits = unembed(cfg, params["embed"], x)
        logits = _shard_logits(logits)
        lp = jax.nn.log_softmax(logits, axis=-1)
        tgt = labels[:, 1:]
        sel = jnp.take_along_axis(lp[:, :-1], tgt[..., None], axis=-1)[..., 0]
        m = (mask[:, 1:] & mask[:, :-1]).astype(jnp.float32)
        return -jnp.sum(sel * m) / jnp.maximum(jnp.sum(m), 1.0)

    # -- serving ---------------------------------------------------------------

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        L = cfg.n_layers
        if cfg.use_mla:
            one = {"ckv": jnp.zeros((batch_size, max_len, cfg.kv_lora_rank),
                                    dtype),
                   "kr": jnp.zeros((batch_size, max_len, cfg.rope_head_dim),
                                   dtype)}
        else:
            one = {"k": jnp.zeros((batch_size, max_len, cfg.n_kv_heads,
                                   cfg.hd), dtype),
                   "v": jnp.zeros((batch_size, max_len, cfg.n_kv_heads,
                                   cfg.hd), dtype)}
        if cfg.scan_layers:
            return jax.tree.map(lambda l: jnp.broadcast_to(
                l[None], (L,) + l.shape), one)
        return [jax.tree.map(jnp.copy, one) for _ in range(L)]

    def prefill(self, params, batch, caches):
        """Fill the cache from a prompt; returns (last-position logits, caches)."""
        cfg = self.cfg
        x, _, _ = self.embed_inputs(params, batch)
        T = x.shape[1]
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]
        x, new_caches = self.backbone(params, x, positions, caches=caches,
                                      cur_len=jnp.int32(0))
        logits = unembed(cfg, params["embed"], x[:, -1:])
        return logits, new_caches

    def decode_step(self, params, tokens, caches, cur_len):
        """tokens: [B, 1] (audio: embeds [B,1,d]).  One-token decode."""
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = tokens.astype(dt_of(cfg))
        else:
            x = embed(cfg, params["embed"], tokens)
        positions = cur_len + jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, new_caches = self.backbone(params, x, positions, caches=caches,
                                      cur_len=cur_len)
        logits = unembed(cfg, params["embed"], x)
        return logits, new_caches


def _none_caches(cfg):
    return None


def _shard_logits(logits):
    from ..distributed.sharding import BATCH, maybe_constraint
    return maybe_constraint(logits, BATCH, None, "model")
