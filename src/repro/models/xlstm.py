"""xLSTM (arXiv:2405.04517): mLSTM + sLSTM blocks, 7:1 interleave.

TPU adaptation notes (DESIGN.md §Arch-applicability):
  * mLSTM's matrix memory is computed in *chunkwise-parallel* form — the same
    matmul-rich reorganization as Mamba-2's SSD — instead of a per-step scan:
    per chunk, intra-chunk gated attention + inter-chunk state passing.  This
    is the MXU-friendly form; the per-step recurrence is used only for decode.
  * sLSTM is inherently sequential (recurrent R matrices); it runs as a
    lax.scan over time with small per-head state — acceptable because only 1
    in 8 blocks is sLSTM and its state is O(d).
  * exponential gating is realized in the stabilized log-domain for the decay
    (cumulative log-sigmoid forget gates); input gates use sigmoid (stabilized
    variant) — recorded as a simplification.

States are O(1) in sequence length → this family runs the long_500k shape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, dt_of, embed, init_embed, init_norm, norm, unembed


# -- chunkwise gated linear attention (the mLSTM core) ---------------------------

def gated_chunk(q, k, v, logf, ig, *, chunk: int, state=None,
                compute_bf16: bool = False):
    """q,k: [B,T,H,dk]; v: [B,T,H,dv]; logf, ig: [B,T,H] (logf<=0, ig>=0).

    y_t = q_t · S_t,   S_t = exp(logf_t)·S_{t-1} + ig_t·k_t v_t^T
    Returns (y [B,T,H,dv], final_state [B,H,dk,dv])."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    Q = min(chunk, T)
    assert T % Q == 0
    nc = T // Q
    scale = 1.0 / math.sqrt(dk)

    cdt = jnp.bfloat16 if compute_bf16 else jnp.float32
    qc = q.astype(cdt).reshape(B, nc, Q, H, dk)
    kc = k.astype(cdt).reshape(B, nc, Q, H, dk)
    vc = v.astype(cdt).reshape(B, nc, Q, H, dv)
    fc = logf.astype(jnp.float32).reshape(B, nc, Q, H)
    ic = ig.astype(jnp.float32).reshape(B, nc, Q, H)

    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)

    def per_chunk(S, inp):
        qb, kb, vb, fb, ib = inp            # [B,Q,H,*]
        L = jnp.cumsum(fb.astype(jnp.float32), axis=1)  # [B,Q,H] inclusive
        # intra-chunk: decay exp(L_i - L_j) for i >= j
        dmat = jnp.exp(L[:, :, None, :] - L[:, None, :, :])       # [B,Q,Q,H]
        dmat = jnp.where((ii >= jj)[None, :, :, None], dmat, 0.0)
        att = jnp.einsum("bihd,bjhd->bijh", qb, kb,
                         preferred_element_type=jnp.float32) * scale
        g = (att * dmat * ib[:, None, :, :]).astype(qb.dtype)
        y = jnp.einsum("bijh,bjhv->bihv", g, vb,
                       preferred_element_type=jnp.float32)
        # inter-chunk: inherited state decayed to position i
        y = y + jnp.einsum("bihd,bih,bhdv->bihv", qb, jnp.exp(L).astype(qb.dtype),
                           S, preferred_element_type=jnp.float32) * scale
        # state update
        w = (jnp.exp(L[:, -1:, :] - L) * ib).astype(qb.dtype)     # [B,Q,H]
        S = S * jnp.exp(L[:, -1, :])[:, :, None, None] \
            + jnp.einsum("bjh,bjhd,bjhv->bhdv", w, kb, vb,
                         preferred_element_type=jnp.float32)
        return S, y

    S0 = (jnp.zeros((B, H, dk, dv), jnp.float32) if state is None
          else state.astype(jnp.float32))
    S, ys = jax.lax.scan(per_chunk, S0,
                         (qc.transpose(1, 0, 2, 3, 4),
                          kc.transpose(1, 0, 2, 3, 4),
                          vc.transpose(1, 0, 2, 3, 4),
                          fc.transpose(1, 0, 2, 3),
                          ic.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, dv)
    return y.astype(q.dtype), S


def gated_step(q, k, v, logf, ig, state, scale):
    """Single-token recurrence (decode).  q,k,v: [B,1,H,d*]."""
    S = state * jnp.exp(logf[:, 0])[..., None, None] \
        + jnp.einsum("bh,bhd,bhv->bhdv", ig[:, 0], k[:, 0].astype(jnp.float32),
                     v[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q[:, 0].astype(jnp.float32), S) * scale
    return y[:, None].astype(q.dtype), S


# -- blocks -----------------------------------------------------------------------

def init_mlstm_block(cfg, key):
    d = cfg.d_model
    di = 2 * d
    H = cfg.n_heads
    dh = di // H
    ks = jax.random.split(key, 7)
    return {
        "ln": init_norm(d, cfg.norm),
        "wup": dense_init(ks[0], (d, 2 * di)),          # x_in, z gate
        "wq": dense_init(ks[1], (di, di)),
        "wk": dense_init(ks[2], (di, di)),
        "wv": dense_init(ks[3], (di, di)),
        "wif": dense_init(ks[4], (di, 2 * H), scale=0.02),
        "out_norm": init_norm(di, "rms"),
        "wdown": dense_init(ks[5], (di, d), scale=1.0 / math.sqrt(di)),
    }


def mlstm_apply(cfg, p, x, state=None, decode=False):
    B, T, d = x.shape
    di = 2 * d
    H = cfg.n_heads
    dh = di // H
    cdt = dt_of(cfg)
    h = norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    up = h @ p["wup"].astype(cdt)
    xin, z = up[..., :di], up[..., di:]
    q = (xin @ p["wq"].astype(cdt)).reshape(B, T, H, dh)
    k = (xin @ p["wk"].astype(cdt)).reshape(B, T, H, dh)
    v = (xin @ p["wv"].astype(cdt)).reshape(B, T, H, dh)
    gates = xin @ p["wif"].astype(cdt)
    ig = jax.nn.sigmoid(gates[..., :H].astype(jnp.float32))
    logf = jax.nn.log_sigmoid(gates[..., H:].astype(jnp.float32))

    if decode:
        y, S = gated_step(q, k, v, logf, ig, state, 1.0 / math.sqrt(dh))
    else:
        y, S = gated_chunk(q, k, v, logf, ig, chunk=cfg.mlstm_chunk,
                           state=state,
                           compute_bf16=getattr(cfg, "mlstm_bf16", False))
    y = y.reshape(B, T, di)
    y = norm(p["out_norm"], y, "rms", cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return x + y @ p["wdown"].astype(cdt), S


def init_slstm_block(cfg, key):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "ln": init_norm(d, cfg.norm),
        "wx": dense_init(ks[0], (d, 4 * d)),            # z, i, f, o
        "r": dense_init(ks[1], (H, dh, 4 * dh), scale=1.0 / math.sqrt(dh)),
        "wout": dense_init(ks[2], (d, d), scale=1.0 / math.sqrt(d)),
    }


def slstm_apply(cfg, p, x, state=None, decode=False):
    """Sequential sLSTM with per-head recurrence.  state: (c, n, h) [B,H,dh]."""
    B, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    cdt = dt_of(cfg)
    inp = norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    pre = (inp @ p["wx"].astype(cdt)).reshape(B, T, H, 4 * dh).astype(jnp.float32)
    r = p["r"]

    if state is None:
        c0 = jnp.zeros((B, H, dh), jnp.float32)
        n0 = jnp.full((B, H, dh), 1e-6, jnp.float32)
        h0 = jnp.zeros((B, H, dh), jnp.float32)
    else:
        c0, n0, h0 = state

    def step(carry, xt):
        c, n, h = carry
        g = xt + jnp.einsum("bhd,hdk->bhk", h, r)
        z, i, f, o = jnp.split(g, 4, axis=-1)
        z, i, f, o = jnp.tanh(z), jax.nn.sigmoid(i), jax.nn.sigmoid(f), \
            jax.nn.sigmoid(o)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h), h

    (c, n, h), hs = jax.lax.scan(step, (c0, n0, h0), pre.transpose(1, 0, 2, 3))
    y = hs.transpose(1, 0, 2, 3).reshape(B, T, d).astype(cdt)
    return x + y @ p["wout"].astype(cdt), (c, n, h)


# -- full model ---------------------------------------------------------------------

class XLSTM:
    def __init__(self, cfg):
        self.cfg = cfg

    def _kinds(self):
        cfg = self.cfg
        k = cfg.slstm_every or 8
        return ["s" if (i % k == k - 1) else "m" for i in range(cfg.n_layers)]

    def init(self, key):
        cfg = self.cfg
        params = {"embed": init_embed(cfg, key),
                  "final_norm": init_norm(cfg.d_model, cfg.norm)}
        keys = jax.random.split(jax.random.fold_in(key, 7), cfg.n_layers)
        blocks = []
        for kind, k in zip(self._kinds(), keys):
            blocks.append(init_mlstm_block(cfg, k) if kind == "m"
                          else init_slstm_block(cfg, k))
        params["blocks"] = blocks
        from .layers import cast_params
        return cast_params(cfg, params)

    def _run(self, params, x, states, decode):
        cfg = self.cfg
        new_states = []
        for kind, bp, st in zip(self._kinds(), params["blocks"], states):
            if kind == "m":
                x, s = mlstm_apply(cfg, bp, x, st, decode)
            else:
                x, s = slstm_apply(cfg, bp, x, st, decode)
            new_states.append(s)
        x = norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        return x, new_states

    def loss(self, params, batch):
        cfg = self.cfg
        x = embed(cfg, params["embed"], batch["tokens"])
        x, _ = self._run(params, x, [None] * cfg.n_layers, decode=False)
        logits = unembed(cfg, params["embed"], x)
        lp = jax.nn.log_softmax(logits, axis=-1)
        tgt = batch["tokens"][:, 1:]
        sel = jnp.take_along_axis(lp[:, :-1], tgt[..., None], axis=-1)[..., 0]
        return -jnp.mean(sel)

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        # recurrent: O(1) state per block — max_len is irrelevant (the point
        # of running long_500k on this family).
        cfg = self.cfg
        B = batch_size
        d = cfg.d_model
        H = cfg.n_heads
        dhm = (2 * d) // H
        dhs = d // H
        states = []
        for kind in self._kinds():
            if kind == "m":
                states.append(jnp.zeros((B, H, dhm, dhm), jnp.float32))
            else:
                states.append((jnp.zeros((B, H, dhs), jnp.float32),
                               jnp.full((B, H, dhs), 1e-6, jnp.float32),
                               jnp.zeros((B, H, dhs), jnp.float32)))
        return states

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        x = embed(cfg, params["embed"], batch["tokens"])
        x, states = self._run(params, x, caches, decode=False)
        logits = unembed(cfg, params["embed"], x[:, -1:])
        return logits, states

    def decode_step(self, params, tokens, caches, cur_len):
        cfg = self.cfg
        x = embed(cfg, params["embed"], tokens)
        x, states = self._run(params, x, caches, decode=True)
        logits = unembed(cfg, params["embed"], x)
        return logits, states
