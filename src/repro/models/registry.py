"""Model registry: ModelConfig.family → model implementation."""
from __future__ import annotations

from .transformer import DecoderLM
from .xlstm import XLSTM
from .zamba import Zamba


def build_model(cfg):
    if cfg.family in ("dense", "moe"):
        return DecoderLM(cfg)
    if cfg.family == "xlstm":
        return XLSTM(cfg)
    if cfg.family == "hybrid":
        return Zamba(cfg)
    raise ValueError(f"unknown family {cfg.family}")
