"""Cluster token-ring model, promoted out of ``examples/cluster_sim.py``.

``n_nodes`` workers run synchronous data-parallel training as a token ring
(the token models the allreduce dependency); ``n_rings`` tokens circulate.
Each hop costs ``lookahead + step_time * draw(dist)``; with probability
``fail_ppm / 1e6`` the hop instead suffers a failure + restart delay.  The
measured quantity is achieved steps/hour vs failure rate — what sizes
checkpoint intervals on a real fleet (Young/Daly).

Unlike PHOLD/queueing, routing here is *deterministic* (ring neighbour), so
almost all traffic is device-local under contiguous placement and only the
ring seam crosses devices — the opposite communication profile from the
uniform-random workloads, which is exactly why the zoo carries it.  With
``dist='dyadic'`` (and the default dyadic-representable ``step_time`` and
``restart_time``) the numpy oracle mirror is bit-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core import events as ev
from ..core.api import EmittedEvents, SimModel

_C_INIT = np.uint32(0xC1A07E57)


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    n_nodes: int = 64
    n_rings: int = 8
    step_time: float = 1.0         # dyadic-representable for bit-exact runs
    fail_ppm: int = 20000          # failures per million hops
    restart_time: float = 25.0     # dyadic-representable
    lookahead: float = 0.5
    dist: str = "dyadic"           # dyadic | uniform24 | exponential
    seed: int = 0                  # replication seed (bootstrap stream salt)


class ClusterModel(SimModel):
    """Objects = worker nodes in a ring; one token event per ring."""

    max_out = 1

    def __init__(self, params: ClusterParams):
        self.params = params

    @property
    def n_objects(self) -> int:
        return self.params.n_nodes

    # -- state ---------------------------------------------------------------

    def init_object_state(self, global_ids: np.ndarray) -> Any:
        n = len(global_ids)
        return {
            "hops": jnp.zeros((n,), jnp.int32),
            "failures": jnp.zeros((n,), jnp.int32),
            "busy_time": jnp.zeros((n,), jnp.float32),
        }

    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        p = self.params
        c = _C_INIT ^ ev.seed_salt_np(p.seed if seed is None else seed)
        # n_rings tokens start at evenly spaced nodes; payload carries the
        # current holder's node id (process_event has no identity input).
        starts = (np.arange(p.n_rings) * (p.n_nodes // p.n_rings)) % p.n_nodes
        s0 = ev._mix_np(np.arange(p.n_rings).astype(np.uint32) ^ c)
        return {
            "dst": starts.astype(np.int32),
            "ts": np.zeros(p.n_rings, np.float32),
            "seed": s0,
            "payload": starts.astype(np.float32),
        }

    # -- ProcessEvent (JAX) ----------------------------------------------------

    def process_event(self, state, ts, seed, payload):
        p = self.params
        seed = seed.astype(jnp.uint32)
        u = ev.draw(ev.fold(seed, 0), p.dist)
        fail = (ev.fold(seed, 1) % jnp.uint32(1_000_000)) \
            < jnp.uint32(p.fail_ppm)
        hop = jnp.float32(p.lookahead) + jnp.float32(p.step_time) * u
        delay = jnp.where(fail, hop + jnp.float32(p.restart_time), hop)

        new_state = {
            "hops": state["hops"] + 1,
            "failures": state["failures"] + fail.astype(jnp.int32),
            "busy_time": state["busy_time"] + delay,
        }
        me = payload.astype(jnp.int32)
        nxt = (me + 1) % p.n_nodes
        out = EmittedEvents(
            dst=nxt[None],
            ts=(ts + delay)[None],
            seed=ev.fold(seed, 3)[None],
            payload=nxt.astype(jnp.float32)[None],
            valid=jnp.ones((1,), bool),
        )
        return new_state, out

    # -- numpy mirror (sequential oracle) --------------------------------------

    def init_object_state_np(self, global_ids: np.ndarray) -> list[dict]:
        return [{
            "hops": np.int32(0),
            "failures": np.int32(0),
            "busy_time": np.float32(0.0),
        } for _ in global_ids]

    def process_event_np(self, st: dict, ts, seed, payload):
        p = self.params
        seed = np.uint32(seed)
        u = ev.draw_np(ev.fold_np(seed, 0), p.dist)
        fail = (ev.fold_np(seed, 1) % np.uint32(1_000_000)) \
            < np.uint32(p.fail_ppm)
        hop = np.float32(np.float32(p.lookahead) + np.float32(p.step_time) * u)
        delay = np.float32(hop + np.float32(p.restart_time)) if fail else hop

        st["hops"] = np.int32(st["hops"] + 1)
        st["failures"] = np.int32(st["failures"] + (1 if fail else 0))
        st["busy_time"] = np.float32(st["busy_time"] + delay)
        me = np.int32(np.float32(payload))
        nxt = np.int32((me + 1) % p.n_nodes)
        return {
            "dst": nxt,
            "ts": np.float32(np.float32(ts) + delay),
            "seed": ev.fold_np(seed, 3),
            "payload": np.float32(nxt),
        }


def make(**overrides) -> ClusterModel:
    if "n_objects" in overrides:                 # workload-agnostic drivers
        overrides["n_nodes"] = overrides.pop("n_objects")
    overrides.pop("initial_events", None)
    return ClusterModel(ClusterParams(**overrides))


CONFORMANCE = dict(
    # high failure rate + short restart so the failure branch is exercised
    # without stalling tokens for most of the short differential horizon.
    model_kw=dict(n_nodes=16, n_rings=4, fail_ppm=150_000, restart_time=4.0,
                  lookahead=0.5, dist="dyadic"),
    n_epochs=40,
    engine_kw=dict(n_buckets=64, bucket_cap=32, route_cap=512,
                   fallback_cap=512),
    dyadic=True,
    supports_batch_impl=False,
)
