"""Hot-spot PHOLD: skewed destinations AND an imbalanced initial population.

The Erlang-PDES load-balancing literature (Toscano et al., PAPERS.md) makes
the point that hot-spot traffic is where load-balancing claims live or die:
uniform PHOLD never gives work stealing anything to do.  This variant creates
a persistent per-object (and therefore per-device) load imbalance two ways:

  * **routing skew** — with probability ``hot_prob/256`` every emitted event
    re-targets one of the first ``hot_objects`` ids (the Phold base model's
    non-uniform routing path, here on by default);
  * **population skew** — the first ``hot_objects`` objects bootstrap with
    ``(1 + hot_boost)×`` the baseline per-object initial events, so the very
    first epoch is already imbalanced instead of waiting for routing skew to
    concentrate the population.

Because contiguous placement puts all hot objects on device 0, a multi-device
run with ``steal=True`` must observe ``stats.stolen > 0`` — the conformance
suite asserts exactly that.  Processing/state logic is inherited from
:class:`repro.phold.model.Phold`, so the JAX/numpy pair stays dyadic-exact.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import events as ev
from ..phold.model import _INIT_C, Phold, PholdParams, _draw_np


@dataclasses.dataclass(frozen=True)
class HotspotParams(PholdParams):
    hot_objects: int = 4
    hot_prob: int = 128       # out of 256
    hot_boost: int = 3        # hot objects start with (1 + boost) * M events


class HotspotPhold(Phold):

    def object_weights(self) -> np.ndarray | None:
        """Routing-skew weights (inherited) plus the population boost: hot
        objects also *start* with ``(1 + hot_boost)×`` the baseline events,
        which dominates early epochs before the routing skew equilibrates."""
        p = self.params
        w = super().object_weights()
        if w is None:
            w = np.full(p.n_objects, 1.0 / p.n_objects, np.float64)
        boost = np.ones(p.n_objects, np.float64)
        boost[:p.hot_objects] += p.hot_boost
        return w * boost

    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        p = self.params
        c = _INIT_C ^ ev.seed_salt_np(p.seed if seed is None else seed)
        counts = np.full(p.n_objects, p.initial_events, np.int64)
        counts[:p.hot_objects] *= 1 + p.hot_boost
        o = np.repeat(np.arange(p.n_objects, dtype=np.uint32), counts)
        m = np.concatenate([np.arange(n, dtype=np.uint32) for n in counts])
        # same (object, sequence-number) seed formula as uniform PHOLD — the
        # skew is purely in how many events each object bootstraps.
        with np.errstate(over="ignore"):
            s0 = ev._mix_np(ev._mix_np(o ^ c) + m * np.uint32(0x9E3779B9))
        ts0 = _draw_np(ev.fold_np(s0, 2), p).astype(np.float32)
        return {
            "dst": o.astype(np.int32),
            "ts": ts0,
            "seed": s0,
            "payload": ev.dyadic10_np(ev.fold_np(s0, 4)).astype(np.float32),
        }


def make(**overrides) -> HotspotPhold:
    return HotspotPhold(HotspotParams(**overrides))


CONFORMANCE = dict(
    model_kw=dict(n_objects=16, initial_events=3, state_nodes=64,
                  realloc_fraction=0.02, lookahead=0.5, dist="dyadic",
                  hot_objects=4, hot_prob=128, hot_boost=3),
    n_epochs=24,
    # hot objects concentrate ~half the population on 4 ids → deep buckets.
    engine_kw=dict(n_buckets=8, bucket_cap=256, route_cap=512,
                   fallback_cap=512),
    dyadic=True,
    supports_batch_impl=True,
)
