"""Workload registry: ``--workload <id>`` → SimModel (configs/registry.py idiom).

Every module under :mod:`repro.workloads` exposes:

  * ``make(**overrides)`` — build the model; all accept ``n_objects`` /
    ``lookahead`` / ``dist`` so drivers can stay workload-agnostic;
  * ``CONFORMANCE`` — the small-scale differential-test recipe consumed by
    :mod:`repro.testing.conformance`:
      ``model_kw``   kwargs for a small oracle-checkable instance
      ``n_epochs``   epochs to run at ``engine_kw``'s default epoch length
      ``engine_kw``  EngineConfig kwargs (capacities sized for the workload)
      ``dyadic``     True → final object state must match the oracle
                     bit-for-bit
      ``supports_batch_impl``  True → the model has ``process_batch`` (Pallas)

Registration contract (the full recipe is ``docs/writing-a-workload.md``):
an id in ``WORKLOADS`` promises a JAX/numpy *pair* — ``process_event`` and
``process_event_np`` with identical counter-based RNG streams and identical
f32 op order — so that under ``dist="dyadic"`` every engine configuration
reproduces the sequential oracle bit-for-bit.  Each id must also appear in
the README zoo table and pin golden digests at two sizes
(:mod:`repro.testing.golden`); the CI docs job
(:mod:`repro.testing.docs_check`) and tests/test_golden.py enforce both.
"""
from __future__ import annotations

import copy
from importlib import import_module

WORKLOADS = {
    "phold": "phold",
    "phold-hotspot": "hotspot",
    "queueing": "queueing",
    "cluster": "cluster",
    "open-queueing": "open_queueing",
    "epidemic": "epidemic",
    "wireless": "wireless",
}


def _module(name: str):
    return import_module(f"repro.workloads.{WORKLOADS[name]}")


def get_workload(name: str, **overrides):
    """Build a registered workload model; overrides go to its params."""
    return _module(name).make(**overrides)


def conformance_spec(name: str) -> dict:
    """The workload's differential-test recipe (deep copy — safe to mutate)."""
    return copy.deepcopy(_module(name).CONFORMANCE)


def all_workloads() -> list[str]:
    return list(WORKLOADS)
