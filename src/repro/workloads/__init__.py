"""The workload zoo: registered SimModels, each with a numpy oracle mirror.

PARSIR's engine techniques — per-object batch processing, disjoint-access
parallelism, work stealing — are claimed fully transparent to model code
(paper §I).  This package is that claim made testable: a registry of diverse
workloads (uniform PHOLD, hot-spot PHOLD, a closed queueing network, a
cluster token-ring, an open queueing network with sources/forks/sinks
exercising multi-emission and absorption, an epidemic SEIR patch model with
state-dependent emission arity, and a wireless cellular/channel model with
a natively hotspot-prone arrival field), every one written twice (JAX for
the engine, numpy for the sequential oracle) with dyadic-exact arithmetic so
the differential conformance harness (:mod:`repro.testing.conformance`) can
assert bit-exact equivalence under every engine configuration.

The add-a-workload recipe is ``docs/writing-a-workload.md``.
"""
from .registry import all_workloads, conformance_spec, get_workload  # noqa: F401
