"""Wireless cellular channel model — the PARSIR paper's experimental lineage
(§IV, ref [28]: GSM-style call/handoff simulation over a cell grid).

Each simulation object is a *cell* managing a fixed bank of radio channels.
The channel state is a **dyadic-grid occupancy vector** ``free_at[C]``: entry
``c`` is the f32 time at which channel ``c`` next becomes free — every value
is a sum of dyadic timestamps and holding times, so it stays exactly
representable and the numpy oracle mirror matches the engine bit-for-bit.

Two event types ride the payload lane (``0.0`` = call arrival from the
cell's own traffic generator, ``1.0`` = handoff arriving from a neighbor):

  * **arrival** — the cell admits the call onto its lowest-indexed free
    channel (``free_at[c] <= ts``) for a dyadic holding time, then re-emits
    its own next arrival (the generator self-loop; hot cells draw the
    inter-arrival gap on a ``2**hot_shift``-finer dyadic grid and may
    bootstrap extra generator streams — the native hotspot).  If **no
    channel is free the call is blocked and absorbed** (``blocked`` ledger).
  * **handoff** — with probability ``handoff_p/256`` an admitted call moves
    to a *geographic neighbor* cell at the end of its holding time (ring
    topology, index wraps at both edges), where it re-runs admission: a full
    neighbor **drops** the handoff (absorption again).  Handoff chains
    continue with the same probability per hop.

Emission arity is state-dependent (``max_out = 2``: generator self-loop +
call lifecycle): a blocked handoff emits nothing, and a cell whose shared
arrival budget (``max_calls``, counted across all its generator streams)
is exhausted stops generating and drains.  The skewed arrival field
makes this the zoo's natively hotspot-prone load — the workload
``placement="adaptive"`` + ``batch_impl="packed"`` (PR 3/4) are measured on
(see ``benchmarks/pdes_perf.py``'s wireless placement ladder).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core import events as ev
from ..core.api import EmittedEvents, SimModel
from ..core.events import ring_neighbor

_WL_INIT = np.uint32(0x3E11C411)

#: payload codes — the event "type" rides the one f32 payload lane.
ARRIVAL, HANDOFF = 0.0, 1.0


@dataclasses.dataclass(frozen=True)
class WirelessParams:
    n_cells: int = 32
    n_channels: int = 4            # channels per cell (occupancy vector width)
    hot_cells: int = 0             # leading cells with boosted traffic
    hot_shift: int = 2             # hot arrival gaps drawn on a 2**k-finer grid
    hot_streams: int = 1           # extra bootstrap generators per hot cell
    handoff_p: int = 96            # per-call handoff probability, out of 256
    max_calls: int = 0             # per-CELL arrival budget shared by all of
    #                                a cell's generator streams; 0 = unbounded
    lookahead: float = 0.5         # L — min gap/holding-time increment
    service_mean: float = 1.0      # scale for non-dyadic draws
    dist: str = "dyadic"           # dyadic | uniform24 | exponential
    seed: int = 0                  # replication seed (bootstrap stream salt)

    def __post_init__(self):
        if self.n_cells < 2:
            raise ValueError(f"n_cells must be >= 2 (ring neighbors), "
                             f"got {self.n_cells}")
        if self.n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {self.n_channels}")
        if not 0 <= self.hot_cells <= self.n_cells:
            raise ValueError(f"hot_cells must be in [0, n_cells], "
                             f"got {self.hot_cells}")
        if not 0 <= self.handoff_p <= 256:
            raise ValueError(f"handoff_p is out of 256, got {self.handoff_p}")


class WirelessModel(SimModel):
    max_out = 2

    def __init__(self, params: WirelessParams):
        self.params = params

    @property
    def n_objects(self) -> int:
        return self.params.n_cells

    def object_weights(self) -> np.ndarray | None:
        """Placement hint: a hot cell carries ``(1 + hot_streams)`` generator
        streams, each firing ~``(L + ½)/(L + ½·2**-hot_shift)`` times as
        often as a cold cell's single stream."""
        p = self.params
        if p.hot_cells == 0:
            return None
        rate = (p.lookahead + 0.5) / (p.lookahead + 0.5 * 2.0 ** -p.hot_shift)
        w = np.ones(p.n_cells, np.float64)
        w[:p.hot_cells] = (1.0 + p.hot_streams) * rate
        return w

    # -- state ---------------------------------------------------------------

    def init_object_state(self, global_ids: np.ndarray) -> Any:
        n, C = len(global_ids), self.params.n_channels
        return {
            "gid": jnp.asarray(global_ids, jnp.int32),
            "free_at": jnp.zeros((n, C), jnp.float32),
            "arrivals": jnp.zeros((n,), jnp.int32),
            "calls": jnp.zeros((n,), jnp.int32),
            "handoffs_in": jnp.zeros((n,), jnp.int32),
            "blocked": jnp.zeros((n,), jnp.int32),
            "dropped": jnp.zeros((n,), jnp.int32),
            "count": jnp.zeros((n,), jnp.int32),
        }

    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        p = self.params
        c = _WL_INIT ^ ev.seed_salt_np(p.seed if seed is None else seed)
        # one generator per cell, (1 + hot_streams) for hot cells.
        counts = np.ones(p.n_cells, np.int64)
        counts[:p.hot_cells] += p.hot_streams
        o = np.repeat(np.arange(p.n_cells, dtype=np.uint32), counts)
        m = np.concatenate([np.arange(n, dtype=np.uint32) for n in counts])
        with np.errstate(over="ignore"):
            s0 = ev._mix_np(ev._mix_np(o ^ c)
                            + m * np.uint32(0x9E3779B9))
        ts0 = ev.draw_np(ev.fold_np(s0, 2), p.dist, p.service_mean)
        return {
            "dst": o.astype(np.int32),
            "ts": ts0.astype(np.float32),
            "seed": s0,
            "payload": np.full(len(o), ARRIVAL, np.float32),
        }

    # -- ProcessEvent (JAX) ----------------------------------------------------

    def process_event(self, state, ts, seed, payload):
        p = self.params
        la = jnp.float32(p.lookahead)
        seed = seed.astype(jnp.uint32)
        is_handoff = payload > jnp.float32(0.5)
        is_hot = state["gid"] < p.hot_cells

        # admission onto the lowest-indexed free channel of the occupancy
        # vector (identical argmax tie-break in the numpy mirror).
        free = state["free_at"] <= ts
        ok = jnp.any(free)
        idx = jnp.argmax(free)
        hold = la + ev.draw(ev.fold(seed, 0), p.dist, p.service_mean)
        depart = ts + hold
        free_at = jnp.where((jnp.arange(p.n_channels) == idx) & ok,
                            depart, state["free_at"])

        one = jnp.int32(1)
        zero = jnp.int32(0)
        admitted = ok.astype(jnp.int32)
        rejected = one - admitted
        arrivals = state["arrivals"] + jnp.where(is_handoff, zero, one)
        new_state = {
            "gid": state["gid"],
            "free_at": free_at,
            "arrivals": arrivals,
            "calls": state["calls"]
            + jnp.where(is_handoff, zero, admitted),
            "handoffs_in": state["handoffs_in"]
            + jnp.where(is_handoff, admitted, zero),
            "blocked": state["blocked"]
            + jnp.where(is_handoff, zero, rejected),
            "dropped": state["dropped"]
            + jnp.where(is_handoff, rejected, zero),
            "count": state["count"] + 1,
        }

        # lane 0: the generator self-loop (arrivals only; hot cells draw the
        # gap on a finer dyadic grid ⇒ higher rate, exactly representable).
        gap_hot = ev.draw_scaled(ev.fold(seed, 1), p.dist, p.hot_shift,
                                 p.service_mean)
        gap_cold = ev.draw(ev.fold(seed, 1), p.dist, p.service_mean)
        ts0 = ts + (la + jnp.where(is_hot, gap_hot, gap_cold))
        budget_ok = jnp.bool_(True) if p.max_calls == 0 \
            else arrivals < jnp.int32(p.max_calls)
        valid0 = (~is_handoff) & budget_ok

        # lane 1: the admitted call's handoff to a ring neighbor at the end
        # of its holding time (blocked/dropped calls emit nothing).
        h = ev.fold(seed, 3)
        valid1 = ok & ((h % jnp.uint32(256)) < jnp.uint32(p.handoff_p))
        dst1 = ring_neighbor(state["gid"],
                             ((h >> jnp.uint32(8)) & jnp.uint32(1)) == 1,
                             p.n_cells)

        out = EmittedEvents(
            dst=jnp.stack([state["gid"], dst1]),
            ts=jnp.stack([ts0, depart]),
            seed=jnp.stack([ev.fold(seed, 4), ev.fold(seed, 5)]),
            payload=jnp.stack([jnp.float32(ARRIVAL), jnp.float32(HANDOFF)]),
            valid=jnp.stack([valid0, valid1]),
        )
        return new_state, out

    # -- numpy mirror (sequential oracle) --------------------------------------

    def init_object_state_np(self, global_ids: np.ndarray) -> list[dict]:
        C = self.params.n_channels
        return [{
            "gid": np.int32(g),
            "free_at": np.zeros(C, np.float32),
            "arrivals": np.int32(0),
            "calls": np.int32(0),
            "handoffs_in": np.int32(0),
            "blocked": np.int32(0),
            "dropped": np.int32(0),
            "count": np.int32(0),
        } for g in global_ids]

    def process_event_np(self, st: dict, ts, seed, payload) -> list[dict]:
        p = self.params
        la = np.float32(p.lookahead)
        seed = np.uint32(seed)
        is_handoff = float(payload) > 0.5
        st["count"] = np.int32(st["count"] + 1)

        free = st["free_at"] <= np.float32(ts)
        ok = bool(np.any(free))
        idx = int(np.argmax(free))
        hold = np.float32(la + ev.draw_np(ev.fold_np(seed, 0), p.dist,
                                          p.service_mean))
        depart = np.float32(np.float32(ts) + hold)
        if ok:
            st["free_at"][idx] = depart
            key = "handoffs_in" if is_handoff else "calls"
        else:
            key = "dropped" if is_handoff else "blocked"
        st[key] = np.int32(st[key] + 1)
        if not is_handoff:
            st["arrivals"] = np.int32(st["arrivals"] + 1)

        out = []
        if not is_handoff:                          # generator self-loop
            if st["gid"] < p.hot_cells:
                gap = ev.draw_scaled_np(ev.fold_np(seed, 1), p.dist,
                                        p.hot_shift, p.service_mean)
            else:
                gap = ev.draw_np(ev.fold_np(seed, 1), p.dist, p.service_mean)
            more = p.max_calls == 0 or int(st["arrivals"]) < p.max_calls
            out.append({"dst": np.int32(st["gid"]),
                        "ts": np.float32(np.float32(ts)
                                         + np.float32(la + gap)),
                        "seed": ev.fold_np(seed, 4),
                        "payload": np.float32(ARRIVAL),
                        "valid": more})
        h = ev.fold_np(seed, 3)
        if ok and int(h % np.uint32(256)) < p.handoff_p:
            out.append({"dst": ring_neighbor(np.int32(st["gid"]),
                                             int((h >> np.uint32(8))
                                                 & np.uint32(1)),
                                             p.n_cells),
                        "ts": depart,
                        "seed": ev.fold_np(seed, 5),
                        "payload": np.float32(HANDOFF)})
        return out


def make(**overrides) -> WirelessModel:
    if "n_objects" in overrides:                 # workload-agnostic drivers
        overrides["n_cells"] = overrides.pop("n_objects")
    overrides.pop("initial_events", None)
    return WirelessModel(WirelessParams(**overrides))


CONFORMANCE = dict(
    # few channels + a hot head so blocking (absorption), handoff chains and
    # the skewed arrival field are all exercised at differential scale.
    model_kw=dict(n_cells=16, n_channels=3, hot_cells=4, hot_shift=2,
                  hot_streams=2, handoff_p=112, lookahead=0.5, dist="dyadic"),
    n_epochs=24,
    engine_kw=dict(n_buckets=8, bucket_cap=64, route_cap=512,
                   fallback_cap=512),
    dyadic=True,
    supports_batch_impl=False,
)
