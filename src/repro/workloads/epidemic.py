"""Epidemic patch model — agent/metapopulation SEIR on a ring of patches.

Each simulation object is a population *patch* holding integer S/E/I/R
compartment counters (susceptible / exposed / infectious / recovered).  Two
event types flow through the engine, distinguished by the payload lane
(``0.0`` = local progression step, ``1.0`` = travel infection):

  * **local step** — the patch advances its own epidemic: a dyadic draw
    promotes exposed → infectious, infectious members expose susceptibles,
    and some infectious recover.  While the patch stays *active*
    (``E + I > 0``) the step re-emits itself (the patch's progression
    chain); once everyone is susceptible-or-recovered the chain **stops** —
    event absorption driven by model state.
  * **travel infection** — with probability ``trans_p/256`` an infectious
    local step also seeds a *geographic neighbor* (ring topology, index
    wraps at both edges): one susceptible there becomes exposed.  A travel
    event landing on a patch with no susceptibles left is absorbed; one
    landing on an *inactive* patch (re)ignites its progression chain.

This is the zoo's test of **state-dependent emission arity**: the same
``process_event`` emits 2, 1 or 0 events purely as a function of patch state
(``max_out = 2``: local progression + travel infection).  All counters are
int32 and all timestamps ride ``dist='dyadic'`` draws, so the numpy oracle
mirror agrees with the engine bit-for-bit; total population
``S + E + I + R`` is conserved per patch by construction (the conservation
ledger tests/test_epidemic.py asserts).

``docs/writing-a-workload.md`` uses this module as its running example —
keep the two mirrors textually parallel when editing.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core import events as ev
from ..core.api import EmittedEvents, SimModel
from ..core.events import ring_neighbor

_EPI_INIT = np.uint32(0xEF1DE31C)

#: payload codes — the event "type" rides the one f32 payload lane.
LOCAL_STEP, TRAVEL = 0.0, 1.0


@dataclasses.dataclass(frozen=True)
class EpidemicParams:
    n_patches: int = 32
    pop: int = 20                  # initial susceptibles per patch
    n_seeds: int = 2               # patches hit by a bootstrap travel event
    trans_p: int = 96              # travel-emission probability, out of 256
    lookahead: float = 0.5         # L — min event-time increment
    service_mean: float = 1.0      # scale for non-dyadic draws
    dist: str = "dyadic"           # dyadic | uniform24 | exponential
    seed: int = 0                  # replication seed (bootstrap stream salt)

    def __post_init__(self):
        if self.n_patches < 2:
            raise ValueError(f"n_patches must be >= 2 (ring neighbors), "
                             f"got {self.n_patches}")
        if not 1 <= self.n_seeds <= self.n_patches:
            raise ValueError(f"n_seeds must be in [1, n_patches], "
                             f"got {self.n_seeds}")
        if not 0 <= self.trans_p <= 256:
            raise ValueError(f"trans_p is out of 256, got {self.trans_p}")


class EpidemicModel(SimModel):
    max_out = 2

    def __init__(self, params: EpidemicParams):
        self.params = params

    @property
    def n_objects(self) -> int:
        return self.params.n_patches

    def _seed_gids(self) -> np.ndarray:
        p = self.params
        return (np.arange(p.n_seeds) * (p.n_patches // p.n_seeds)) \
            % p.n_patches

    def object_weights(self) -> np.ndarray | None:
        """Placement hint: seeded patches (and so their neighborhoods) carry
        the early-epidemic event mass before travel spreads it out."""
        p = self.params
        w = np.ones(p.n_patches, np.float64)
        w[self._seed_gids()] += 3.0
        return w

    # -- state ---------------------------------------------------------------

    def init_object_state(self, global_ids: np.ndarray) -> Any:
        n = len(global_ids)
        p = self.params
        return {
            "gid": jnp.asarray(global_ids, jnp.int32),
            "s": jnp.full((n,), p.pop, jnp.int32),
            "e": jnp.zeros((n,), jnp.int32),
            "i": jnp.zeros((n,), jnp.int32),
            "r": jnp.zeros((n,), jnp.int32),
            "imports": jnp.zeros((n,), jnp.int32),
            "count": jnp.zeros((n,), jnp.int32),
            "last_ts": jnp.zeros((n,), jnp.float32),
        }

    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        p = self.params
        c = _EPI_INIT ^ ev.seed_salt_np(p.seed if seed is None else seed)
        gids = self._seed_gids()
        s0 = ev._mix_np(gids.astype(np.uint32) ^ c)
        ts0 = ev.draw_np(ev.fold_np(s0, 2), p.dist, p.service_mean)
        return {
            "dst": gids.astype(np.int32),
            "ts": ts0.astype(np.float32),
            "seed": s0,
            "payload": np.full(p.n_seeds, TRAVEL, np.float32),
        }

    # -- ProcessEvent (JAX) ----------------------------------------------------

    def process_event(self, state, ts, seed, payload):
        p = self.params
        la = jnp.float32(p.lookahead)
        seed = seed.astype(jnp.uint32)
        s, e, i, r = state["s"], state["e"], state["i"], state["r"]
        is_travel = payload > jnp.float32(0.5)
        zero = jnp.int32(0)

        # travel branch: seed one S → E if any susceptibles remain.
        seeded = is_travel & (s > 0)
        was_active = (e + i) > 0

        # local branch: promote E → I, expose S → E, recover I → R — in that
        # order, with independent counter draws (the numpy mirror repeats the
        # identical sequence).
        promote = jnp.minimum(e, (ev.fold(seed, 0) % jnp.uint32(3))
                              .astype(jnp.int32))
        i_loc1 = i + promote
        expose = jnp.where(i_loc1 > 0,
                           jnp.minimum(s, (ev.fold(seed, 1) % jnp.uint32(4))
                                       .astype(jnp.int32)), zero)
        recover = jnp.minimum(i_loc1, (ev.fold(seed, 2) % jnp.uint32(2))
                              .astype(jnp.int32))
        local = ~is_travel

        one = seeded.astype(jnp.int32)
        new_state = {
            "gid": state["gid"],
            "s": jnp.where(is_travel, s - one, s - expose),
            "e": jnp.where(is_travel, e + one, e - promote + expose),
            "i": jnp.where(is_travel, i, i_loc1 - recover),
            "r": jnp.where(is_travel, r, r + recover),
            "imports": state["imports"] + one,
            "count": state["count"] + 1,
            "last_ts": ts,
        }
        active_after = (new_state["e"] + new_state["i"]) > 0

        # lane 0: the patch's own progression chain.  A local step continues
        # while active; a travel event only *starts* a chain on a previously
        # inactive patch (so each patch runs at most one chain at a time).
        valid0 = jnp.where(is_travel, seeded & ~was_active, active_after)
        d0 = ev.draw(ev.fold(seed, 3), p.dist, p.service_mean)
        ts0 = ts + (la + d0)

        # lane 1: travel infection to a ring neighbor (local steps only,
        # requires infectious members surviving the step).
        route = ev.fold(seed, 5)
        valid1 = local & (new_state["i"] > 0) \
            & ((route % jnp.uint32(256)) < jnp.uint32(p.trans_p))
        dst1 = ring_neighbor(state["gid"],
                             ((route >> jnp.uint32(8)) & jnp.uint32(1)) == 1,
                             p.n_patches)
        d1 = ev.draw(ev.fold(seed, 4), p.dist, p.service_mean)
        ts1 = ts + (la + d1)

        out = EmittedEvents(
            dst=jnp.stack([state["gid"], dst1]),
            ts=jnp.stack([ts0, ts1]),
            seed=jnp.stack([ev.fold(seed, 6), ev.fold(seed, 7)]),
            payload=jnp.stack([jnp.float32(LOCAL_STEP), jnp.float32(TRAVEL)]),
            valid=jnp.stack([valid0, valid1]),
        )
        return new_state, out

    # -- numpy mirror (sequential oracle) --------------------------------------

    def init_object_state_np(self, global_ids: np.ndarray) -> list[dict]:
        p = self.params
        return [{
            "gid": np.int32(g),
            "s": np.int32(p.pop),
            "e": np.int32(0),
            "i": np.int32(0),
            "r": np.int32(0),
            "imports": np.int32(0),
            "count": np.int32(0),
            "last_ts": np.float32(0.0),
        } for g in global_ids]

    def process_event_np(self, st: dict, ts, seed, payload) -> list[dict]:
        p = self.params
        la = np.float32(p.lookahead)
        seed = np.uint32(seed)
        st["count"] = np.int32(st["count"] + 1)
        st["last_ts"] = np.float32(ts)

        if float(payload) > 0.5:                       # travel infection
            seeded = int(st["s"]) > 0
            was_active = int(st["e"]) + int(st["i"]) > 0
            if seeded:
                st["s"] = np.int32(st["s"] - 1)
                st["e"] = np.int32(st["e"] + 1)
                st["imports"] = np.int32(st["imports"] + 1)
            if not (seeded and not was_active):
                return []                              # absorbed
            d0 = ev.draw_np(ev.fold_np(seed, 3), p.dist, p.service_mean)
            return [{"dst": np.int32(st["gid"]),
                     "ts": np.float32(np.float32(ts) + np.float32(la + d0)),
                     "seed": ev.fold_np(seed, 6),
                     "payload": np.float32(LOCAL_STEP)}]

        # local progression step — promote, expose, recover (same draw order
        # as the JAX branch).
        promote = min(int(st["e"]), int(ev.fold_np(seed, 0) % np.uint32(3)))
        i1 = int(st["i"]) + promote
        expose = min(int(st["s"]),
                     int(ev.fold_np(seed, 1) % np.uint32(4))) if i1 > 0 else 0
        recover = min(i1, int(ev.fold_np(seed, 2) % np.uint32(2)))
        st["s"] = np.int32(int(st["s"]) - expose)
        st["e"] = np.int32(int(st["e"]) - promote + expose)
        st["i"] = np.int32(i1 - recover)
        st["r"] = np.int32(int(st["r"]) + recover)

        out = []
        if int(st["e"]) + int(st["i"]) > 0:            # chain continues
            d0 = ev.draw_np(ev.fold_np(seed, 3), p.dist, p.service_mean)
            out.append({"dst": np.int32(st["gid"]),
                        "ts": np.float32(np.float32(ts)
                                         + np.float32(la + d0)),
                        "seed": ev.fold_np(seed, 6),
                        "payload": np.float32(LOCAL_STEP)})
        route = ev.fold_np(seed, 5)
        if int(st["i"]) > 0 and int(route % np.uint32(256)) < p.trans_p:
            d1 = ev.draw_np(ev.fold_np(seed, 4), p.dist, p.service_mean)
            out.append({"dst": ring_neighbor(np.int32(st["gid"]),
                                             int((route >> np.uint32(8))
                                                 & np.uint32(1)),
                                             p.n_patches),
                        "ts": np.float32(np.float32(ts)
                                         + np.float32(la + d1)),
                        "seed": ev.fold_np(seed, 7),
                        "payload": np.float32(TRAVEL)})
        return out


def make(**overrides) -> EpidemicModel:
    if "n_objects" in overrides:                 # workload-agnostic drivers
        overrides["n_patches"] = overrides.pop("n_objects")
    overrides.pop("initial_events", None)
    return EpidemicModel(EpidemicParams(**overrides))


CONFORMANCE = dict(
    # enough susceptibles + seeds that the epidemic stays active over the
    # short differential horizon, high trans_p so travel (fan-out) traffic
    # and chain reignition are both exercised.
    model_kw=dict(n_patches=16, pop=12, n_seeds=3, trans_p=128,
                  lookahead=0.5, dist="dyadic"),
    n_epochs=24,
    engine_kw=dict(n_buckets=8, bucket_cap=64, route_cap=512,
                   fallback_cap=512),
    dyadic=True,
    supports_batch_impl=False,
)
