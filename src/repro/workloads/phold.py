"""Classic uniform PHOLD (paper §IV-A), registered in the workload zoo.

The model itself lives in :mod:`repro.phold.model`; this module only binds it
to the registry contract (``make`` + ``CONFORMANCE``).
"""
from __future__ import annotations

from ..phold.model import Phold, PholdParams


def make(**overrides) -> Phold:
    return Phold(PholdParams(**overrides))


CONFORMANCE = dict(
    model_kw=dict(n_objects=16, initial_events=4, state_nodes=64,
                  realloc_fraction=0.02, lookahead=0.5, dist="dyadic"),
    n_epochs=24,
    engine_kw=dict(n_buckets=8, bucket_cap=64, route_cap=512,
                   fallback_cap=512),
    dyadic=True,
    supports_batch_impl=True,
)
