"""Closed queueing network — DESP-C++'s reference validation scenario.

``n_jobs`` jobs circulate forever among ``n_stations`` single-server FIFO
stations (a closed Jackson-style network).  An event is "job arrives at
station at ``ts``": the server starts it at ``max(ts, busy_until)``, holds it
for ``lookahead + draw(dist)`` time units, and forwards it to a uniformly
random next station at the departure time.  Since each processed event emits
exactly one successor the job population is conserved — the same invariant
the PHOLD tests use — and with ``dist='dyadic'`` every timestamp, wait and
busy-time accumulator stays on the 1/1024 grid, so engine and numpy oracle
agree bit-for-bit.

The FIFO coupling through ``busy_until`` makes this a stronger ordering test
than PHOLD: processing two arrivals at one station out of timestamp order
produces a *different* (wrong) departure schedule, not just a reordered one.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core import events as ev
from ..core.api import EmittedEvents, SimModel

_Q_INIT = np.uint32(0x5E12F00D)


@dataclasses.dataclass(frozen=True)
class QueueingParams:
    n_stations: int = 64
    n_jobs: int = 256              # closed population (jobs never leave)
    lookahead: float = 0.5         # L — min service time, engine lookahead
    service_mean: float = 1.0      # scale for non-dyadic service draws
    dist: str = "dyadic"           # dyadic | uniform24 | exponential
    seed: int = 0                  # replication seed (bootstrap stream salt)


class ClosedQueueingNetwork(SimModel):
    max_out = 1

    def __init__(self, params: QueueingParams):
        self.params = params

    @property
    def n_objects(self) -> int:
        return self.params.n_stations

    # -- state ---------------------------------------------------------------

    def init_object_state(self, global_ids: np.ndarray) -> Any:
        n = len(global_ids)
        return {
            "busy_until": jnp.zeros((n,), jnp.float32),
            "served": jnp.zeros((n,), jnp.int32),
            "busy_time": jnp.zeros((n,), jnp.float32),
            "wait_time": jnp.zeros((n,), jnp.float32),
        }

    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        p = self.params
        c = _Q_INIT ^ ev.seed_salt_np(p.seed if seed is None else seed)
        j = np.arange(p.n_jobs, dtype=np.uint32)
        s0 = ev._mix_np(j ^ c)
        ts0 = ev.draw_np(ev.fold_np(s0, 2), p.dist, p.service_mean)
        return {
            "dst": (j % np.uint32(p.n_stations)).astype(np.int32),
            "ts": ts0.astype(np.float32),
            "seed": s0,
            "payload": j.astype(np.float32),    # the job id rides the payload
        }

    # -- ProcessEvent (JAX) ----------------------------------------------------

    def process_event(self, state, ts, seed, payload):
        p = self.params
        seed = seed.astype(jnp.uint32)
        service = jnp.float32(p.lookahead) + ev.draw(
            ev.fold(seed, 0), p.dist, p.service_mean)
        begin = jnp.maximum(ts, state["busy_until"])
        depart = begin + service                 # >= ts + lookahead
        new_state = {
            "busy_until": depart,
            "served": state["served"] + 1,
            "busy_time": state["busy_time"] + service,
            "wait_time": state["wait_time"] + (begin - ts),
        }
        dst = (ev.fold(seed, 1) % jnp.uint32(p.n_stations)).astype(jnp.int32)
        out = EmittedEvents(
            dst=dst[None],
            ts=depart[None],
            seed=ev.fold(seed, 3)[None],
            payload=payload[None],               # job identity is conserved
            valid=jnp.ones((1,), bool),
        )
        return new_state, out

    # -- numpy mirror (sequential oracle) --------------------------------------

    def init_object_state_np(self, global_ids: np.ndarray) -> list[dict]:
        return [{
            "busy_until": np.float32(0.0),
            "served": np.int32(0),
            "busy_time": np.float32(0.0),
            "wait_time": np.float32(0.0),
        } for _ in global_ids]

    def process_event_np(self, st: dict, ts, seed, payload):
        p = self.params
        seed = np.uint32(seed)
        service = np.float32(np.float32(p.lookahead)
                             + ev.draw_np(ev.fold_np(seed, 0), p.dist,
                                          p.service_mean))
        begin = np.float32(max(np.float32(ts), st["busy_until"]))
        depart = np.float32(begin + service)
        st["busy_until"] = depart
        st["served"] = np.int32(st["served"] + 1)
        st["busy_time"] = np.float32(st["busy_time"] + service)
        st["wait_time"] = np.float32(st["wait_time"] + (begin - np.float32(ts)))
        return {
            "dst": np.int32(ev.fold_np(seed, 1) % np.uint32(p.n_stations)),
            "ts": depart,
            "seed": ev.fold_np(seed, 3),
            "payload": np.float32(payload),
        }


def make(**overrides) -> ClosedQueueingNetwork:
    if "n_objects" in overrides:                 # workload-agnostic drivers
        overrides["n_stations"] = overrides.pop("n_objects")
    overrides.pop("initial_events", None)
    return ClosedQueueingNetwork(QueueingParams(**overrides))


CONFORMANCE = dict(
    model_kw=dict(n_stations=16, n_jobs=64, lookahead=0.5, dist="dyadic"),
    n_epochs=24,
    engine_kw=dict(n_buckets=8, bucket_cap=96, route_cap=512,
                   fallback_cap=512),
    dyadic=True,
    supports_batch_impl=False,
)
