"""Open queueing network — sources, forks and absorbing sinks (DESP-C++'s
source/resource/sink decomposition, made an engine conformance workload).

This is the first workload to exercise the engine's *generalized* event-flow
contract end to end: variable emission arity with ``max_out = 2`` fan-out and
absorption (all-invalid emission rows).  Topology, by contiguous global-id
ranges::

    sources → stage-1 queues → forks → stage-2 queues → sinks
    [0, S)    [S, S+Q1)        ...                       [.., n_objects)

  * **source** — a self-clocked arrival generator ("Poisson-ish": dyadic /
    exponential inter-arrival gaps).  Each firing emits TWO events: its own
    next firing (the self-loop) and a fresh job to a uniformly random
    stage-1 queue.  With ``max_jobs > 0`` the self-loop goes invalid after
    that many jobs — the network then drains to empty.
  * **queue** (both stages) — single-server FIFO exactly like the closed
    network: start at ``max(ts, busy_until)``, hold ``lookahead + draw``,
    forward at departure.  Emits ONE event (second lane invalid).
  * **fork** — splits each job into two independent copies headed to two
    random stage-2 queues (``max_out = 2`` fan-out on service completion).
  * **sink** — absorbs: counts the arrival, accumulates the job's sojourn
    time (the payload carries its birth timestamp), and emits NOTHING.

With ``dist='dyadic'`` every timestamp and accumulator stays on the 1/1024
grid, so the engine and the numpy oracle mirror agree bit-for-bit; the numpy
mirror returns *lists* of event dicts (empty for sinks, ``valid: False`` for
an exhausted source's self-loop) — the oracle-side face of the variable-arity
contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core import events as ev
from ..core.api import EmittedEvents, SimModel

_OQ_INIT = np.uint32(0x0BE9F10D)

#: state["kind"] codes, in global-id order.
SOURCE, STAGE1, FORK, STAGE2, SINK = 0, 1, 2, 3, 4


@dataclasses.dataclass(frozen=True)
class OpenQueueingParams:
    n_sources: int = 8
    n_stage1: int = 16
    n_forks: int = 8
    n_stage2: int = 16
    n_sinks: int = 8
    lookahead: float = 0.5         # L — min gap/service time
    service_mean: float = 1.0      # scale for non-dyadic draws
    dist: str = "dyadic"           # dyadic | uniform24 | exponential
    max_jobs: int = 0              # per-source job budget; 0 = unbounded
    seed: int = 0                  # replication seed (bootstrap stream salt)

    def __post_init__(self):
        for role in ("n_sources", "n_stage1", "n_forks", "n_stage2",
                     "n_sinks"):
            if getattr(self, role) < 1:
                raise ValueError(f"{role} must be >= 1 (every role's routing "
                                 f"is modulo its count), got "
                                 f"{getattr(self, role)}")

    @property
    def n_objects(self) -> int:
        return (self.n_sources + self.n_stage1 + self.n_forks
                + self.n_stage2 + self.n_sinks)

    @property
    def offsets(self) -> tuple[int, int, int, int]:
        """(stage1, fork, stage2, sink) first global ids."""
        o1 = self.n_sources
        o2 = o1 + self.n_stage1
        o3 = o2 + self.n_forks
        o4 = o3 + self.n_stage2
        return o1, o2, o3, o4


class OpenQueueingNetwork(SimModel):
    max_out = 2

    def __init__(self, params: OpenQueueingParams):
        self.params = params

    @property
    def n_objects(self) -> int:
        return self.params.n_objects

    def _kind_of(self, gids: np.ndarray) -> np.ndarray:
        return np.searchsorted(np.asarray(self.params.offsets),
                               np.asarray(gids), side="right").astype(np.int32)

    # -- state ---------------------------------------------------------------

    def init_object_state(self, global_ids: np.ndarray) -> Any:
        n = len(global_ids)
        return {
            "kind": jnp.asarray(self._kind_of(global_ids), jnp.int32),
            "gid": jnp.asarray(global_ids, jnp.int32),
            "count": jnp.zeros((n,), jnp.int32),
            "busy_until": jnp.zeros((n,), jnp.float32),
            "busy_time": jnp.zeros((n,), jnp.float32),
            "wait_time": jnp.zeros((n,), jnp.float32),
            "sojourn": jnp.zeros((n,), jnp.float32),
        }

    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        p = self.params
        c = _OQ_INIT ^ ev.seed_salt_np(p.seed if seed is None else seed)
        i = np.arange(p.n_sources, dtype=np.uint32)
        s0 = ev._mix_np(i ^ c)
        ts0 = ev.draw_np(ev.fold_np(s0, 2), p.dist, p.service_mean)
        return {
            "dst": i.astype(np.int32),
            "ts": ts0.astype(np.float32),
            "seed": s0,
            "payload": np.zeros(p.n_sources, np.float32),
        }

    # -- ProcessEvent (JAX) ----------------------------------------------------

    def process_event(self, state, ts, seed, payload):
        p = self.params
        o_q1, o_fork, o_q2, o_sink = p.offsets
        la = jnp.float32(p.lookahead)
        seed = seed.astype(jnp.uint32)
        kind = state["kind"]
        is_source = kind == SOURCE
        is_queue = (kind == STAGE1) | (kind == STAGE2)
        is_sink = kind == SINK

        draw_a = ev.draw(ev.fold(seed, 0), p.dist, p.service_mean)
        draw_b = ev.draw(ev.fold(seed, 2), p.dist, p.service_mean)
        route_a = ev.fold(seed, 1)
        route_b = ev.fold(seed, 6)

        # queue dynamics (selected only where is_queue)
        service = la + draw_a
        begin = jnp.maximum(ts, state["busy_until"])
        depart = begin + service

        count = state["count"] + 1
        new_state = {
            "kind": kind,
            "gid": state["gid"],
            "count": count,
            "busy_until": jnp.where(is_queue, depart, state["busy_until"]),
            "busy_time": state["busy_time"]
            + jnp.where(is_queue, service, jnp.float32(0.0)),
            "wait_time": state["wait_time"]
            + jnp.where(is_queue, begin - ts, jnp.float32(0.0)),
            "sojourn": state["sojourn"]
            + jnp.where(is_sink, ts - payload, jnp.float32(0.0)),
        }

        def pick(u, n, off):
            return jnp.int32(off) + (u % jnp.uint32(n)).astype(jnp.int32)

        # lane 0: source self-loop | queue departure | fork first copy.
        hop_q = jnp.where(kind == STAGE1, pick(route_a, p.n_forks, o_fork),
                          pick(route_a, p.n_sinks, o_sink))
        dst0 = jnp.where(is_source, state["gid"],
                         jnp.where(is_queue, hop_q,
                                   pick(route_a, p.n_stage2, o_q2)))
        ts0 = jnp.where(is_queue, depart, ts + (la + draw_a))
        more_jobs = jnp.bool_(True) if p.max_jobs == 0 \
            else count < jnp.int32(p.max_jobs)
        valid0 = jnp.where(is_sink, False,
                           jnp.where(is_source, more_jobs, True))
        pay0 = jnp.where(is_source, jnp.float32(0.0), payload)

        # lane 1: source's fresh job | fork second copy (else invalid).
        valid1 = is_source | (kind == FORK)
        dst1 = jnp.where(is_source, pick(route_a, p.n_stage1, o_q1),
                         pick(route_b, p.n_stage2, o_q2))
        ts1 = ts + (la + draw_b)
        pay1 = jnp.where(is_source, ts1, payload)  # a new job's birth stamp

        out = EmittedEvents(
            dst=jnp.stack([dst0, dst1]),
            ts=jnp.stack([ts0, ts1]),
            seed=jnp.stack([ev.fold(seed, 4), ev.fold(seed, 5)]),
            payload=jnp.stack([pay0, pay1]),
            valid=jnp.stack([valid0, valid1]),
        )
        return new_state, out

    # -- numpy mirror (sequential oracle) --------------------------------------

    def init_object_state_np(self, global_ids: np.ndarray) -> list[dict]:
        kinds = self._kind_of(global_ids)
        return [{
            "kind": np.int32(k),
            "gid": np.int32(g),
            "count": np.int32(0),
            "busy_until": np.float32(0.0),
            "busy_time": np.float32(0.0),
            "wait_time": np.float32(0.0),
            "sojourn": np.float32(0.0),
        } for g, k in zip(global_ids, kinds)]

    def process_event_np(self, st: dict, ts, seed, payload) -> list[dict]:
        p = self.params
        o_q1, o_fork, o_q2, o_sink = p.offsets
        la = np.float32(p.lookahead)
        seed = np.uint32(seed)
        kind = int(st["kind"])
        draw_a = ev.draw_np(ev.fold_np(seed, 0), p.dist, p.service_mean)
        st["count"] = np.int32(st["count"] + 1)

        def pick(u, n, off):
            return np.int32(off + int(np.uint32(u) % np.uint32(n)))

        if kind == SINK:
            st["sojourn"] = np.float32(st["sojourn"]
                                       + (np.float32(ts) - np.float32(payload)))
            return []

        if kind == SOURCE:
            draw_b = ev.draw_np(ev.fold_np(seed, 2), p.dist, p.service_mean)
            ts_self = np.float32(np.float32(ts) + np.float32(la + draw_a))
            ts_job = np.float32(np.float32(ts) + np.float32(la + draw_b))
            more = p.max_jobs == 0 or int(st["count"]) < p.max_jobs
            return [
                {"dst": np.int32(st["gid"]), "ts": ts_self,
                 "seed": ev.fold_np(seed, 4), "payload": np.float32(0.0),
                 "valid": more},
                {"dst": pick(ev.fold_np(seed, 1), p.n_stage1, o_q1),
                 "ts": ts_job, "seed": ev.fold_np(seed, 5),
                 "payload": ts_job},
            ]

        if kind == FORK:
            draw_b = ev.draw_np(ev.fold_np(seed, 2), p.dist, p.service_mean)
            return [
                {"dst": pick(ev.fold_np(seed, 1), p.n_stage2, o_q2),
                 "ts": np.float32(np.float32(ts) + np.float32(la + draw_a)),
                 "seed": ev.fold_np(seed, 4), "payload": np.float32(payload)},
                {"dst": pick(ev.fold_np(seed, 6), p.n_stage2, o_q2),
                 "ts": np.float32(np.float32(ts) + np.float32(la + draw_b)),
                 "seed": ev.fold_np(seed, 5), "payload": np.float32(payload)},
            ]

        # FIFO queue (stage 1 or 2)
        service = np.float32(la + draw_a)
        begin = np.float32(max(np.float32(ts), st["busy_until"]))
        depart = np.float32(begin + service)
        st["busy_until"] = depart
        st["busy_time"] = np.float32(st["busy_time"] + service)
        st["wait_time"] = np.float32(st["wait_time"]
                                     + (begin - np.float32(ts)))
        if kind == STAGE1:
            dst = pick(ev.fold_np(seed, 1), p.n_forks, o_fork)
        else:
            dst = pick(ev.fold_np(seed, 1), p.n_sinks, o_sink)
        return [{"dst": dst, "ts": depart, "seed": ev.fold_np(seed, 4),
                 "payload": np.float32(payload)}]


def make(**overrides) -> OpenQueueingNetwork:
    if "n_objects" in overrides:                 # workload-agnostic drivers
        n = overrides.pop("n_objects")
        if n < 5:
            raise ValueError(f"open-queueing needs n_objects >= 5 (one per "
                             f"role), got {n}")
        roles = ("n_sources", "n_stage1", "n_forks", "n_stage2", "n_sinks")
        clash = [r for r in roles if r in overrides]
        if clash:
            # honoring both silently would build a network whose total size
            # differs from the n_objects the driver asked for.
            raise ValueError(f"pass either n_objects or explicit role counts, "
                             f"not both (got n_objects and {clash})")
        q = n // 5
        overrides.update(n_sources=q, n_stage1=q, n_forks=q, n_stage2=q,
                         n_sinks=n - 4 * q)
    overrides.pop("initial_events", None)
    return OpenQueueingNetwork(OpenQueueingParams(**overrides))


CONFORMANCE = dict(
    model_kw=dict(n_sources=4, n_stage1=4, n_forks=4, n_stage2=4, n_sinks=4,
                  lookahead=0.5, dist="dyadic"),
    n_epochs=24,
    engine_kw=dict(n_buckets=8, bucket_cap=64, route_cap=512,
                   fallback_cap=512),
    dyadic=True,
    supports_batch_impl=False,
)
