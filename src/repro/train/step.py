"""Train-step factory: loss → grads → AdamW, with optional microbatch
accumulation, built to be jit-lowered with explicit shardings (dry-run and
real runs share this code path)."""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.sharding import BATCH, maybe_constraint
from . import optimizer as opt


def make_train_step(model, tcfg, grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    grad_shardings: optional pytree of NamedShardings (usually the param
    shardings).  Constraining the grads to the param layout is the ZeRO-2
    trick: XLA must produce *sharded* grads, so the data-parallel reduction
    lowers to reduce-scatter instead of a full-tensor all-reduce — critical
    for FSDP-stored MoE experts (EXPERIMENTS §Perf cell B)."""

    def loss_fn(params, batch):
        batch = jax.tree.map(lambda x: maybe_constraint(x, BATCH), batch)
        return model.loss(params, batch)

    def shard_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
            grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            k = tcfg.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape((k, b // k) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbi):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbi)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), ()

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = shard_grads(grads)

        params, opt_state, metrics = opt.update(grads, opt_state, params, tcfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
