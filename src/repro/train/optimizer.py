"""AdamW + global-norm clip + warmup-cosine schedule, in pure JAX.

Optimizer state shards exactly like the parameters (same pytree structure), so
the ZeRO-style memory layout falls out of the params sharding rules for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def init(params) -> AdamWState:
    z = lambda p: jnp.zeros_like(p)
    return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params),
                      jnp.zeros((), jnp.int32))


def schedule(step, tcfg):
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def update(grads, state: AdamWState, params, tcfg):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, tcfg.grad_clip)
    count = state.count + 1
    lr = schedule(count.astype(jnp.float32), tcfg)
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** count.astype(jnp.float32))
        vh = v / (1 - b2 ** count.astype(jnp.float32))
        step_val = mh / (jnp.sqrt(vh) + eps) + wd * pf
        return (pf - lr * step_val).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, AdamWState(new_m, new_v, count), metrics
