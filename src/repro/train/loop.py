"""Training loop: jit'd sharded step + checkpoint/restart + supervision.

The loop is deliberately crash-tolerant end to end:
  * state checkpoints atomically every ``checkpoint_every`` steps;
  * on start it resumes from LATEST if present (restart == resume);
  * the SupervisedStep wrapper retries transient step failures and tracks
    straggler statistics;
  * batches come from the deterministic pipeline keyed by step index, so a
    resumed run consumes exactly the batches it would have.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..checkpoint import ckpt
from ..data.synthetic import SyntheticLoader
from ..ft.supervisor import SupervisedStep
from . import optimizer as opt
from .step import make_train_step


class Trainer:
    def __init__(self, model, tcfg, mesh=None, loader: Optional[Any] = None,
                 log: Callable[[str], None] = print):
        self.model, self.tcfg, self.mesh, self.log = model, tcfg, mesh, log
        self.loader = loader
        step_fn = make_train_step(model, tcfg)
        if mesh is not None:
            from ..distributed.sharding import (batch_shardings,
                                                params_shardings)
            pshape = jax.eval_shape(model.init,
                                    jax.eval_shape(lambda: jax.random.key(0)))
            psh = params_shardings(pshape, mesh)
            osh = params_shardings(jax.eval_shape(opt.init, pshape), mesh)
            self._psh, self._osh = psh, osh
            # out_shardings pinned to the input shardings: otherwise the
            # compiler may pick different output placements and the next
            # call's donated args no longer match in_shardings.
            self._jit = jax.jit(step_fn, in_shardings=(psh, osh, None),
                                out_shardings=(psh, osh, None),
                                donate_argnums=(0, 1))
        else:
            self._psh = self._osh = None
            self._jit = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step_fn = SupervisedStep(self._jit)

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.key(seed))
        return params, opt.init(params)

    def resume_or_init(self, seed: int = 0):
        d = self.tcfg.checkpoint_dir
        last = ckpt.latest_step(d)
        params, opt_state = self.init_state(seed)
        if last is None:
            return params, opt_state, 0
        shard = ({"params": self._psh, "opt": self._osh}
                 if self._psh is not None else None)
        tree, step = ckpt.restore(d, {"params": params, "opt": opt_state},
                                  shardings=shard)
        self.log(f"[train] resumed from step {step}")
        return tree["params"], tree["opt"], step

    def run(self, n_steps: int, seed: int = 0, start=None):
        if start is None:
            params, opt_state, step0 = self.resume_or_init(seed)
        else:
            params, opt_state, step0 = start
        metrics_hist = []
        for step in range(step0, n_steps):
            batch = self.loader.batch_at(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            dt = time.perf_counter() - t0
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = step
            m["step_s"] = dt
            metrics_hist.append(m)
            if step % 10 == 0 or step == n_steps - 1:
                self.log(f"[train] step {step} loss {m['loss']:.4f} "
                         f"gnorm {m['grad_norm']:.3f} ({dt*1e3:.0f} ms)")
            if self.tcfg.checkpoint_every and \
                    (step + 1) % self.tcfg.checkpoint_every == 0:
                ckpt.save(self.tcfg.checkpoint_dir, step + 1,
                          {"params": params, "opt": opt_state},
                          keep=self.tcfg.keep_checkpoints)
        return params, opt_state, metrics_hist
