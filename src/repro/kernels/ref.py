"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core import events as ev


def event_apply_ref(payload, addresses, top, ts, seed, cnt, *,
                    n_objects: int, lookahead: float, K: int, KR: int,
                    dist: str = "dyadic", mean: float = 1.0,
                    hot_objects: int = 0, hot_prob: int = 0):
    """Oracle for kernels/event_apply.py.

    Same signature/layout as the kernel: payload [n, LANES, S].  Applies each
    object's sorted batch sequentially with plain jnp ops.
    """
    n, LANES, S = payload.shape
    C = ts.shape[1]

    def draw(bits):
        if dist == "dyadic":
            return ev.dyadic10(bits)
        if dist == "uniform24":
            return ev.uniform24(bits) * jnp.float32(mean)
        if dist == "exponential":
            return -jnp.log1p(-ev.uniform24(bits)) * jnp.float32(mean)
        raise ValueError(dist)

    def per_object(pay, addr, tp, ts_row, seed_row, c):
        odst = jnp.zeros((C,), jnp.int32)
        ots = jnp.full((C,), jnp.inf, jnp.float32)
        oseed = jnp.zeros((C,), jnp.uint32)
        opay = jnp.zeros((C,), jnp.float32)
        ovalid = jnp.zeros((C,), jnp.int32)

        def body(r, carry):
            pay, addr, tp, odst, ots, oseed, opay, ovalid = carry

            def apply(args):
                pay, addr, tp, odst, ots, oseed, opay, ovalid = args
                t = ts_row[r]
                s = seed_row[r]
                start = (ev.fold(s, 0) % jnp.uint32(S - K + 1)).astype(jnp.int32)
                delta = ev.dyadic10(ev.fold(s, 5))
                win = jax.lax.dynamic_slice(pay, (0, start), (LANES, K))
                pay = jax.lax.dynamic_update_slice(
                    pay, win * jnp.float32(0.5) + delta, (0, start))
                top2 = tp - KR
                freed = start + KR - 1 - jnp.arange(KR, dtype=jnp.int32)
                addr = jax.lax.dynamic_update_slice(addr, freed, (top2,))
                initval = ev.dyadic10(ev.fold(s, 6))
                pay = jax.lax.dynamic_update_slice(
                    pay, jnp.full((LANES, KR), initval, jnp.float32), (0, start))
                dst = (ev.fold(s, 1) % jnp.uint32(n_objects)).astype(jnp.int32)
                if hot_objects and hot_prob:
                    hot = (ev.fold(s, 8) & jnp.uint32(255)) \
                        < jnp.uint32(hot_prob)
                    hdst = (ev.fold(s, 9) % jnp.uint32(hot_objects)
                            ).astype(jnp.int32)
                    dst = jnp.where(hot, hdst, dst)
                odst = odst.at[r].set(dst)
                ots = ots.at[r].set(t + jnp.float32(lookahead)
                                    + draw(ev.fold(s, 2)))
                oseed = oseed.at[r].set(ev.fold(s, 3))
                opay = opay.at[r].set(ev.dyadic10(ev.fold(s, 4)))
                ovalid = ovalid.at[r].set(1)
                return pay, addr, tp, odst, ots, oseed, opay, ovalid

            return jax.lax.cond(r < c, apply, lambda a: a,
                                (pay, addr, tp, odst, ots, oseed, opay, ovalid))

        out = jax.lax.fori_loop(0, C, body,
                                (pay, addr, tp, odst, ots, oseed, opay, ovalid))
        return out

    return jax.vmap(per_object)(payload, addresses, top, ts, seed, cnt)


def attention_ref(q, k, v, *, causal: bool = True):
    """Oracle for kernels/flash_attention.py: exact softmax attention w/ GQA.

    q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D]."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_ref(x, dt, A, B, C_mat):
    """Oracle for kernels/ssd_scan.py: sequential Mamba-2 SSD recurrence.

    x:  [b, T, H, P]   (input after in-proj/conv, per head)
    dt: [b, T, H]      (positive step sizes, post-softplus)
    A:  [H]            (negative scalars per head)
    B:  [b, T, N]      (input projection to state, shared across heads)
    C_mat: [b, T, N]   (output projection from state)
    returns y: [b, T, H, P] with  h_t = exp(A*dt_t) h_{t-1} + dt_t * B_t x_t^T;
    y_t = C_t^T h_t  (state h: [H, N, P]).
    """
    b, T, H, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(A * dtt)[:, None, None]          # [H,1,1]
        upd = (dtt[:, None] * Bt[None, :])[:, :, None] * xt[:, None, :]
        h = h * decay + upd                              # [H, N, P]
        y = jnp.einsum("n,hnp->hp", Ct, h)
        return h, y

    def per_batch(xb, dtb, Bb, Cb):
        h0 = jnp.zeros((H, N, P), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb, dtb, Bb, Cb))
        return ys

    return jax.vmap(per_batch)(x.astype(jnp.float32), dt.astype(jnp.float32),
                               B.astype(jnp.float32), C_mat.astype(jnp.float32)
                               ).astype(x.dtype)
