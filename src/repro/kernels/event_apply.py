"""Pallas TPU kernel: per-object batched event application (PHOLD hot loop).

This is the paper's core locality idea (§II-A) mapped to the TPU memory
hierarchy: PARSIR keeps a simulation object cache-hot while a worker thread
processes the object's whole epoch batch; here the object's state tile is
loaded **once** into VMEM, every event of its batch is applied in timestamp
order by an in-kernel loop, and the state is written back **once**.  HBM
traffic per epoch drops from O(events x touched-state) to O(state), which is
exactly the paper's cache-miss argument restated for HBM<->VMEM.

Layout notes (TPU adaptation, see DESIGN.md §2):
  * node payloads are [LANES, S] per object — the long node axis is the lane
    (minor) dimension, a multiple of 128 for S >= 128, so Mosaic tiles it
    without padding blowup; LANES rides the sublane axis.
  * the touch window is a contiguous dynamic slice (the model guarantees no
    wraparound), so reads/writes are dense vector ops, not gathers.
  * the arena free+alloc pair is the paper's stack allocator: a contiguous
    store into ``addresses[top-KR : top)`` — LIFO reuse keeps the write in the
    same VMEM-resident tile.

Grid: one program instance per simulation object (the grid dimension is
"arbitrary"/sequential-safe; instances are independent).  Events, counts and
emitted-event buffers ride in VMEM blocks alongside the state.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_C1 = 0x9E3779B9
_C2 = 0x85EBCA6B
_C3 = 0xC2B2AE35
_FOLD = 0x632BE59B


def _mix(z):
    z = (z + jnp.uint32(_C1)).astype(jnp.uint32)
    z = (z ^ (z >> jnp.uint32(16))) * jnp.uint32(_C2)
    z = (z ^ (z >> jnp.uint32(13))) * jnp.uint32(_C3)
    return z ^ (z >> jnp.uint32(16))


def _fold(seed, k: int):
    return _mix(seed ^ jnp.uint32((k * _FOLD) & 0xFFFFFFFF))


def _dyadic10(bits):
    return (bits & jnp.uint32(1023)).astype(jnp.float32) * jnp.float32(1.0 / 1024.0)


def _uniform24(bits):
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _draw(bits, dist: str, mean: float):
    if dist == "dyadic":
        return _dyadic10(bits)
    if dist == "uniform24":
        return _uniform24(bits) * jnp.float32(mean)
    if dist == "exponential":
        return -jnp.log1p(-_uniform24(bits)) * jnp.float32(mean)
    raise ValueError(dist)


def _kernel(ts_ref, seed_ref, cnt_ref,
            payload_in, addr_in, top_in,
            payload_out, addr_out, top_out,
            odst, ots, oseed, opay, ovalid,
            *, S, K, KR, LANES, C, n_objects, lookahead, dist, mean,
            hot_objects=0, hot_prob=0):
    # state tile becomes "hot": copied into the output VMEM block once.
    payload_out[...] = payload_in[...]
    addr_out[...] = addr_in[...]
    top_out[...] = top_in[...]
    ots[...] = jnp.full((1, C), jnp.inf, jnp.float32)
    odst[...] = jnp.zeros((1, C), jnp.int32)
    oseed[...] = jnp.zeros((1, C), jnp.uint32)
    opay[...] = jnp.zeros((1, C), jnp.float32)
    ovalid[...] = jnp.zeros((1, C), jnp.int32)

    cnt = cnt_ref[0]

    def body(r, _):
        @pl.when(r < cnt)
        def _apply():
            ts = ts_ref[0, r]
            seed = seed_ref[0, r]
            start = (_fold(seed, 0) % jnp.uint32(S - K + 1)).astype(jnp.int32)
            delta = _dyadic10(_fold(seed, 5))

            # touch: one contiguous VMEM read+write of the hot window.
            # (leading block dim indexed with dslice(0, 1): bare python ints in
            # pl.load/pl.store index tuples break interpret-mode discharge.)
            row0 = pl.dslice(0, 1)
            rows = pl.load(payload_out, (row0, slice(None), pl.dslice(start, K)))
            pl.store(payload_out, (row0, slice(None), pl.dslice(start, K)),
                     rows * jnp.float32(0.5) + delta)

            # arena: free KR touched nodes then alloc KR (LIFO — stack alloc).
            top = top_out[0]
            top2 = top - KR
            freed = start + KR - 1 - jnp.arange(KR, dtype=jnp.int32)
            pl.store(addr_out, (row0, pl.dslice(top2, KR)), freed[None])
            initval = _dyadic10(_fold(seed, 6))
            pl.store(payload_out, (row0, slice(None), pl.dslice(start, KR)),
                     jnp.full((1, LANES, KR), initval, jnp.float32))
            # net top unchanged: free KR then alloc KR.

            # emit one event (ScheduleNewEvent)
            dst = (_fold(seed, 1) % jnp.uint32(n_objects)).astype(jnp.int32)
            if hot_objects and hot_prob:
                hot = (_fold(seed, 8) & jnp.uint32(255)) < jnp.uint32(hot_prob)
                hot_dst = (_fold(seed, 9) % jnp.uint32(hot_objects)
                           ).astype(jnp.int32)
                dst = jnp.where(hot, hot_dst, dst)
            odst[0, r] = dst
            ots[0, r] = ts + jnp.float32(lookahead) + _draw(_fold(seed, 2), dist, mean)
            oseed[0, r] = _fold(seed, 3)
            opay[0, r] = _dyadic10(_fold(seed, 4))
            ovalid[0, r] = 1
        return 0

    jax.lax.fori_loop(0, C, body, 0)


def build_event_apply(*, S: int, LANES: int, C: int, K: int, KR: int,
                      n_objects: int, lookahead: float, dist: str,
                      mean: float, interpret: bool = True,
                      hot_objects: int = 0, hot_prob: int = 0):
    """Build a jit-able pallas_call for fixed static geometry."""
    kern = functools.partial(_kernel, S=S, K=K, KR=KR, LANES=LANES, C=C,
                             n_objects=n_objects, lookahead=lookahead,
                             dist=dist, mean=mean, hot_objects=hot_objects,
                             hot_prob=hot_prob)

    def call(payload, addresses, top, ts, seed, cnt):
        n = payload.shape[0]
        grid = (n,)
        out_shape = [
            jax.ShapeDtypeStruct((n, LANES, S), jnp.float32),
            jax.ShapeDtypeStruct((n, S), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n, C), jnp.int32),
            jax.ShapeDtypeStruct((n, C), jnp.float32),
            jax.ShapeDtypeStruct((n, C), jnp.uint32),
            jax.ShapeDtypeStruct((n, C), jnp.float32),
            jax.ShapeDtypeStruct((n, C), jnp.int32),
        ]
        row = lambda i: (i, 0)
        row3 = lambda i: (i, 0, 0)
        one = lambda i: (i,)
        in_specs = [
            pl.BlockSpec((1, C), row),            # ts
            pl.BlockSpec((1, C), row),            # seed
            pl.BlockSpec((1,), one),              # cnt
            pl.BlockSpec((1, LANES, S), row3),    # payload
            pl.BlockSpec((1, S), row),            # addresses
            pl.BlockSpec((1,), one),              # top
        ]
        out_specs = [
            pl.BlockSpec((1, LANES, S), row3),
            pl.BlockSpec((1, S), row),
            pl.BlockSpec((1,), one),
            pl.BlockSpec((1, C), row),
            pl.BlockSpec((1, C), row),
            pl.BlockSpec((1, C), row),
            pl.BlockSpec((1, C), row),
            pl.BlockSpec((1, C), row),
        ]
        return pl.pallas_call(
            kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape, interpret=interpret,
        )(ts, seed, cnt, payload, addresses, top)

    return call
