"""jit'd public wrappers for the Pallas kernels (+ layout/padding adapters).

Every op takes ``use_pallas``/``interpret`` switches: on this CPU container the
kernels execute via ``interpret=True`` (validated against ref.py); on real TPU
hardware the same pallas_call lowers to Mosaic.  The pure-jnp fallbacks are the
production path used by the dry-run (XLA:CPU cannot compile Mosaic kernels).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import ref
from .event_apply import build_event_apply
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan


# -- event apply -------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _event_apply_cached(S, LANES, C, K, KR, n_objects, lookahead, dist, mean,
                        interpret, hot_objects, hot_prob):
    call = build_event_apply(S=S, LANES=LANES, C=C, K=K, KR=KR,
                             n_objects=n_objects, lookahead=lookahead,
                             dist=dist, mean=mean, interpret=interpret,
                             hot_objects=hot_objects, hot_prob=hot_prob)
    return jax.jit(call)


def event_apply(payload, addresses, top, ts, seed, cnt, *, n_objects: int,
                lookahead: float, K: int, KR: int, dist: str = "dyadic",
                mean: float = 1.0, interpret: bool = True,
                use_pallas: bool = True, hot_objects: int = 0,
                hot_prob: int = 0):
    """Batched per-object event application.  payload: [n, LANES, S]."""
    n, LANES, S = payload.shape
    C = ts.shape[1]
    if not use_pallas:
        return ref.event_apply_ref(payload, addresses, top, ts, seed, cnt,
                                   n_objects=n_objects, lookahead=lookahead,
                                   K=K, KR=KR, dist=dist, mean=mean,
                                   hot_objects=hot_objects, hot_prob=hot_prob)
    fn = _event_apply_cached(S, LANES, C, K, KR, n_objects, lookahead, dist,
                             mean, interpret, hot_objects, hot_prob)
    return fn(payload, addresses, top, ts, seed, cnt)


# -- attention ----------------------------------------------------------------

def mha(q, k, v, *, causal: bool = True, bq: int = 128, bk: int = 128,
        interpret: bool = True, use_pallas: bool = True):
    """GQA attention.  q: [B,Hq,Tq,D]; k,v: [B,Hkv,Tk,D]."""
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal)
    B, Hq, Tq, D = q.shape
    Tk = k.shape[2]
    bq_, bk_ = min(bq, max(8, Tq)), min(bk, max(8, Tk))
    pq = (-Tq) % bq_
    pk = (-Tk) % bk_
    if pk and not causal:
        raise ValueError("non-causal attention requires Tk % bk == 0")
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention(q, k, v, causal=causal, bq=bq_, bk=bk_,
                          interpret=interpret)
    return out[:, :, :Tq, :]


# -- SSD ----------------------------------------------------------------------

def ssd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True,
        use_pallas: bool = True):
    """Mamba-2 SSD.  x: [b,T,H,P]; dt: [b,T,H]; A: [H]; B,C: [b,T,N]."""
    if not use_pallas:
        return ref.ssd_ref(x, dt, A, B, C)
    b, T, H, P = x.shape
    ch = min(chunk, T) if T % min(chunk, T) == 0 else chunk
    pad = (-T) % ch
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 → identity update
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan(x, dt, A, B, C, chunk=ch, interpret=interpret)
    return y[:, :T]
