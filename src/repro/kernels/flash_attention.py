"""Pallas TPU kernel: GQA flash attention (forward).

The perf-critical compute layer of the LM substrate (prefill path).  Online-
softmax tiling: the grid's last dimension walks key/value blocks sequentially
("arbitrary" semantics on TPU) while running max / normalizer / accumulator
live in VMEM scratch — the working set per instance is
(bq x d) + 2 x (bk x d) + (bq x bk), all MXU-aligned (block sizes are
multiples of 128).

GQA is expressed in the BlockSpec index maps: query head h reads KV head
``h // group`` — no materialized KV repetition (saves HBM bandwidth, which is
the dominant roofline term for decode-heavy shapes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
            scale, causal, bq, bk, seq_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * jnp.float32(scale)                  # [bq, bk]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_i[...] = m_new

    if causal:
        # block skip: fully-masked k blocks do no compute (their loads are
        # prefetched by the BlockSpec machinery regardless — acceptable; the
        # win is skipped MXU work on ~half the blocks).
        pl.when((ki * bk) <= (qi * bq + bq - 1))(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_i[...], 1e-30)
        o_ref[0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D].  Returns [B, Hq, Tq, D].

    Tq % bq == 0 and Tk % bk == 0 (ops.py pads); Hq % Hkv == 0 (GQA).
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0 and Tq % bq == 0 and Tk % bk == 0
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    qr = q.reshape(B * Hq, Tq, D)
    kr = k.reshape(B * Hkv, Tk, D)
    vr = v.reshape(B * Hkv, Tk, D)

    grid = (B * Hq, Tq // bq, Tk // bk)

    def qmap(bh, qi, ki):
        return (bh, qi, 0)

    def kvmap(bh, qi, ki):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // group, ki, 0)

    kern = functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                             bk=bk, seq_k=Tk)
    from jax.experimental.pallas import tpu as pltpu
    scratch = [
        pltpu.VMEM((bq, D), jnp.float32),   # acc
        pltpu.VMEM((bq,), jnp.float32),     # running max
        pltpu.VMEM((bq,), jnp.float32),     # running normalizer
    ]

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), qmap),
            pl.BlockSpec((1, bk, D), kvmap),
            pl.BlockSpec((1, bk, D), kvmap),
        ],
        out_specs=pl.BlockSpec((1, bq, D), qmap),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Tq, D)
