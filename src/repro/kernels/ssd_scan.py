"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

The sequential SSM recurrence  h_t = exp(A·dt_t)·h_{t-1} + dt_t·B_t x_t^T,
y_t = C_t^T h_t  is reorganized into chunkwise matmuls (the SSD algorithm),
which is the TPU-native adaptation: instead of a length-T scalar scan (VPU
bound), each Q-length chunk does four MXU matmuls —

    intra:  y += ((C Bᵀ) ⊙ M̃) x          [Q,N]x[N,Q], [Q,Q]x[Q,P]
    inter:  y += (C ⊙ e^L) h_prev        [Q,N]x[N,P]
    state:  h  = e^{L_Q} h_prev + (B ⊙ w)ᵀ x   [N,Q]x[Q,P]

with the inter-chunk state h ([N, P] per head) carried in VMEM scratch across
the sequential chunk grid dimension.  Used by the zamba2 (Mamba-2 hybrid)
architecture; the pure-jnp chunked form in models/mamba2.py mirrors the same
math for the non-Pallas path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h, *, Q, H):
    bh = pl.program_id(0)
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h[...] = jnp.zeros_like(h)

    a = a_ref[bh % H]
    dt = dt_ref[0].astype(jnp.float32)                    # [Q]
    x = x_ref[0].astype(jnp.float32)                      # [Q, P]
    Bm = b_ref[0].astype(jnp.float32)                     # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                     # [Q, N]

    l = jnp.cumsum(a * dt)                                # [Q] inclusive
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(l[:, None] - l[None, :]), 0.0)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    G = G * decay * dt[None, :]                           # [Q, Q]
    y = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + jax.lax.dot_general(Cm * jnp.exp(l)[:, None], h[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    w = jnp.exp(l[Q - 1] - l) * dt                        # [Q]
    h[...] = jnp.exp(l[Q - 1]) * h[...] + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """x: [b, T, H, P]; dt: [b, T, H]; A: [H]; B, C: [b, T, N].

    Returns y: [b, T, H, P].  T % chunk == 0 (ops.py pads)."""
    b, T, H, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0
    Q = chunk

    xr = x.transpose(0, 2, 1, 3).reshape(b * H, T, P)
    dtr = dt.transpose(0, 2, 1).reshape(b * H, T)
    grid = (b * H, T // Q)

    out = pl.pallas_call(
        functools.partial(_kernel, Q=Q, H=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, Q), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: (bh // H, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: (bh // H, ci, 0)),
            pl.BlockSpec((H,), lambda bh, ci: (0,)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, B, C, A.astype(jnp.float32))
    return out.reshape(b, H, T, P).transpose(0, 2, 1, 3)
