"""PHOLD benchmark model (paper §IV-A, Table II).

State of each object = linked lists of chunks in the paper's extended PHOLD;
here: a node arena ``payload[S, LANES]`` plus the stack allocator of
:mod:`repro.phold.arena` (addresses/top — the paper's Fig 1 layout).  An event

  * touches ``S/32`` of the nodes (read + write, mimicking the busy-channel
    scans of [28] in the paper),
  * reallocates a fraction ``P`` of the state via free/alloc pairs through the
    stack allocator (the paper's malloc/free interception path),
  * emits exactly one new event with a uniformly random destination and a
    timestamp increment ``lookahead + draw(dist)`` — so global event population
    is conserved at ``O*M``, as in classic PHOLD.

Every implementation exists twice: in JAX (engine) and in numpy
(sequential-oracle mirror, same op order).  With ``dist='dyadic'`` all floats
are exact dyadics and the two agree bit-for-bit (see core/events.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import events as ev
from ..core.api import EmittedEvents, SimModel
from . import arena as ar

_INIT_C = np.uint32(0xA511E9B3)


@dataclasses.dataclass(frozen=True)
class PholdParams:
    n_objects: int = 1024          # O
    initial_events: int = 10       # M
    state_nodes: int = 4000        # S (list nodes per object)
    realloc_fraction: float = 0.001  # P
    lookahead: float = 0.5         # L (simulation-time units)
    mean_increment: float = 1.0    # TA scale for the draw
    dist: str = "dyadic"           # dyadic | uniform24 | exponential
    lanes: int = 6                 # payload lanes per node (~32B chunks)
    # non-uniform routing (paper §IV-A: "uniform or non-uniform distribution"):
    # with probability hot_prob/256 the new event targets one of the first
    # hot_objects ids — a skewed workload that exercises work stealing.
    hot_objects: int = 0
    hot_prob: int = 0              # out of 256
    # replication seed: salts the bootstrap event stream only (seed=0 is the
    # historical stream); see SimModel.initial_events.
    seed: int = 0

    @property
    def touch(self) -> int:
        return max(1, self.state_nodes // 32)

    @property
    def realloc_k(self) -> int:
        return max(1, int(math.ceil(self.realloc_fraction * self.state_nodes)))


def _draw(bits, params: PholdParams):
    return ev.draw(bits, params.dist, params.mean_increment)


def _draw_np(bits, params: PholdParams):
    return ev.draw_np(bits, params.dist, params.mean_increment)


class Phold(SimModel):
    max_out = 1

    def __init__(self, params: PholdParams):
        self.params = params

    @property
    def n_objects(self) -> int:
        return self.params.n_objects

    # -- state ---------------------------------------------------------------

    def init_object_state(self, global_ids: np.ndarray) -> Any:
        n = len(global_ids)
        S, LN = self.params.state_nodes, self.params.lanes
        # initial payload from the object id — deterministic, device-agnostic.
        g = np.asarray(global_ids, np.uint32)
        base = ev.dyadic10_np(ev.fold_np(ev._mix_np(g ^ _INIT_C), 7))  # [n]
        payload = np.broadcast_to(base[:, None, None], (n, S, LN)).astype(np.float32)
        return {
            "payload": jnp.asarray(payload),
            "addresses": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (n, S)),
            "top": jnp.full((n,), S, jnp.int32),
        }

    def object_weights(self) -> np.ndarray | None:
        """Expected steady-state event share per object (placement hint).

        With non-uniform routing, every emission lands on one of the first
        ``hot_objects`` ids with probability ``hot_prob/256`` — so in steady
        state that mass concentrates there regardless of where events start.
        Uniform routing carries no skew: return None (equal split).
        """
        p = self.params
        if not (p.hot_objects and p.hot_prob):
            return None
        h = p.hot_prob / 256.0
        w = np.full(p.n_objects, (1.0 - h) / p.n_objects, np.float64)
        w[:p.hot_objects] += h / p.hot_objects
        return w

    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        p = self.params
        c = _INIT_C ^ ev.seed_salt_np(p.seed if seed is None else seed)
        o = np.repeat(np.arange(p.n_objects, dtype=np.uint32), p.initial_events)
        m = np.tile(np.arange(p.initial_events, dtype=np.uint32), p.n_objects)
        with np.errstate(over="ignore"):
            s0 = ev._mix_np(ev._mix_np(o ^ c) + m * np.uint32(0x9E3779B9))
        ts0 = _draw_np(ev.fold_np(s0, 2), p).astype(np.float32)
        return {
            "dst": o.astype(np.int32),
            "ts": ts0,
            "seed": s0,
            "payload": ev.dyadic10_np(ev.fold_np(s0, 4)).astype(np.float32),
        }

    # -- ProcessEvent (JAX) ----------------------------------------------------

    def process_event(self, state, ts, seed, payload):
        p = self.params
        S, K, KR = p.state_nodes, p.touch, p.realloc_k
        seed = seed.astype(jnp.uint32)

        # contiguous touch window (no wraparound) — keeps the hot region a
        # single dynamic slice, which is what the Pallas event_apply kernel
        # loads into VMEM (see kernels/event_apply.py).
        start = (ev.fold(seed, 0) % jnp.uint32(S - K + 1)).astype(jnp.int32)
        idx = start + jnp.arange(K, dtype=jnp.int32)
        del payload  # PHOLD's handler keys everything off the event seed
        delta = ev.dyadic10(ev.fold(seed, 5))
        rows = state["payload"][idx]                       # [K, LANES] gather
        state_payload = state["payload"].at[idx].set(
            rows * jnp.float32(0.5) + delta)

        a = ar.Arena(state["addresses"], state["top"])
        a = ar.free_k(a, idx[:KR])
        a, got = ar.alloc_k(a, KR)
        initval = ev.dyadic10(ev.fold(seed, 6))
        state_payload = state_payload.at[got].set(
            jnp.full((KR, p.lanes), 0.0, jnp.float32) + initval)

        dst = (ev.fold(seed, 1) % jnp.uint32(p.n_objects)).astype(jnp.int32)
        if p.hot_objects and p.hot_prob:
            hot = ((ev.fold(seed, 8) & jnp.uint32(255))
                   < jnp.uint32(p.hot_prob))
            hot_dst = (ev.fold(seed, 9) % jnp.uint32(p.hot_objects)
                       ).astype(jnp.int32)
            dst = jnp.where(hot, hot_dst, dst)
        ts_out = ts + jnp.float32(p.lookahead) + _draw(ev.fold(seed, 2), p)
        out = EmittedEvents(
            dst=dst[None],
            ts=ts_out[None],
            seed=ev.fold(seed, 3)[None],
            payload=ev.dyadic10(ev.fold(seed, 4))[None],
            valid=jnp.ones((1,), bool),
        )
        new_state = {"payload": state_payload, "addresses": a.addresses, "top": a.top}
        return new_state, out

    # -- whole-batch ProcessEvent via the Pallas kernel ------------------------

    def process_batch(self, state, ts_s, seed_s, pay_s, cnt_b, lookahead,
                      use_pallas: bool = True, interpret: bool = True):
        """Apply each object's sorted epoch batch in one kernel call
        (kernels/event_apply.py — the VMEM-hot analogue of the paper's
        cache-hot batch execution).  Drop-in for the engine's rounds loop."""
        from ..core.api import EmittedEvents  # noqa: F401 (doc parity)
        from ..core.events import EventBatch
        from ..kernels import ops
        p = self.params
        payload = jnp.swapaxes(state["payload"], 1, 2)   # [n,S,LN] → [n,LN,S]
        (pay2, addr2, top2, odst, ots, oseed, opay, ovalid) = ops.event_apply(
            payload, state["addresses"], state["top"], ts_s, seed_s, cnt_b,
            n_objects=p.n_objects, lookahead=p.lookahead, K=p.touch,
            KR=p.realloc_k, dist=p.dist, mean=p.mean_increment,
            interpret=interpret, use_pallas=use_pallas,
            hot_objects=p.hot_objects, hot_prob=p.hot_prob)
        new_state = {"payload": jnp.swapaxes(pay2, 1, 2),
                     "addresses": addr2, "top": top2}
        valid = ovalid.astype(bool)
        out = EventBatch(dst=odst.reshape(-1), ts=ots.reshape(-1),
                         seed=oseed.reshape(-1), payload=opay.reshape(-1),
                         valid=valid.reshape(-1))
        lv = jnp.sum((valid & (ots < ts_s + jnp.float32(lookahead))
                      ).astype(jnp.int32))
        return new_state, out, lv

    # -- numpy mirror (sequential oracle) --------------------------------------

    def process_event_np(self, st: dict, ts, seed, payload):
        p = self.params
        S, K, KR = p.state_nodes, p.touch, p.realloc_k
        seed = np.uint32(seed)

        start = np.int32(ev.fold_np(seed, 0) % np.uint32(S - K + 1))
        idx = start + np.arange(K, dtype=np.int32)
        delta = ev.dyadic10_np(ev.fold_np(seed, 5))
        st["payload"][idx] = st["payload"][idx] * np.float32(0.5) + delta

        st["addresses"], st["top"] = ar.free_k_np(st["addresses"], st["top"], idx[:KR])
        st["addresses"], st["top"], got = ar.alloc_k_np(st["addresses"], st["top"], KR)
        st["payload"][got] = ev.dyadic10_np(ev.fold_np(seed, 6))

        dst = np.int32(ev.fold_np(seed, 1) % np.uint32(p.n_objects))
        if p.hot_objects and p.hot_prob:
            if (ev.fold_np(seed, 8) & np.uint32(255)) < np.uint32(p.hot_prob):
                dst = np.int32(ev.fold_np(seed, 9) % np.uint32(p.hot_objects))
        ts_out = np.float32(np.float32(ts) + np.float32(p.lookahead)
                            + _draw_np(ev.fold_np(seed, 2), p))
        return {
            "dst": dst,
            "ts": ts_out,
            "seed": ev.fold_np(seed, 3),
            "payload": ev.dyadic10_np(ev.fold_np(seed, 4)),
        }

    def init_object_state_np(self, global_ids: np.ndarray) -> list[dict]:
        S, LN = self.params.state_nodes, self.params.lanes
        out = []
        for g in np.asarray(global_ids, np.uint32):
            base = ev.dyadic10_np(ev.fold_np(ev._mix_np(g ^ _INIT_C), 7))
            addresses, top = ar.arena_init_np(S)
            out.append({
                "payload": np.full((S, LN), base, np.float32),
                "addresses": addresses,
                "top": top,
            })
        return out
