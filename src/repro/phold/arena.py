"""The per-object stack allocator (paper §II-C, Fig 1), vectorized.

PARSIR's allocator keeps, per object and chunk size, an ``addresses`` array of
deliverable chunk pointers and a ``top_elem`` cursor::

    alloc:  return addresses[top_elem++]
    free:   addresses[--top_elem] = addr

i.e. free chunks live at ``addresses[top : count)``.  We keep that discipline
verbatim over *indices* into a preallocated node arena (placement-by-sharding
replaces mmap+mbind — the arena array lives in the owning device's HBM by
construction, see DESIGN.md §2).  All functions below operate on a single
object and are vmapped by the model; ``k`` is static.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Arena(NamedTuple):
    addresses: jax.Array  # i32 [n_nodes] — stack of free-chunk indices at [top:]
    top: jax.Array        # i32 scalar


def arena_init(n_nodes: int) -> Arena:
    """All nodes allocated: empty free region (top == count)."""
    return Arena(jnp.arange(n_nodes, dtype=jnp.int32),
                 jnp.asarray(n_nodes, jnp.int32))


def free_k(a: Arena, idxs: jax.Array) -> Arena:
    """Release k chunks: addresses[--top] = addr, vectorized."""
    k = idxs.shape[0]
    top2 = a.top - k
    pos = top2 + jnp.arange(k, dtype=jnp.int32)
    # paper order: successive frees push downward → last freed at lowest slot.
    return Arena(a.addresses.at[pos].set(idxs[::-1], mode="drop"), top2)


def alloc_k(a: Arena, k: int) -> tuple[Arena, jax.Array]:
    """Allocate k chunks: return addresses[top++], vectorized (LIFO)."""
    pos = a.top + jnp.arange(k, dtype=jnp.int32)
    vals = a.addresses[jnp.clip(pos, 0, a.addresses.shape[0] - 1)]
    return Arena(a.addresses, a.top + k), vals


# numpy mirror (sequential oracle) -------------------------------------------

def arena_init_np(n_nodes: int):
    return np.arange(n_nodes, dtype=np.int32), np.int32(n_nodes)


def free_k_np(addresses, top, idxs):
    k = len(idxs)
    top2 = top - k
    addresses[top2:top2 + k] = np.asarray(idxs, np.int32)[::-1]
    return addresses, np.int32(top2)


def alloc_k_np(addresses, top, k):
    vals = addresses[top:top + k].copy()
    return addresses, np.int32(top + k), vals
