"""Fault tolerance: supervised stepping, straggler detection, elastic restart.

What "fault tolerant at 1000+ nodes" means for this framework:

  * **Checkpoint/restart** — the train loop checkpoints atomically every
    ``checkpoint_every`` steps (checkpoint/ckpt.py) and `resume()` restores
    the latest consistent state, including after a mid-save crash.
  * **Failure detection + bounded retry** — `SupervisedStep` wraps the jitted
    step; a device/runtime failure raises in the host process, is classified,
    and triggers restore-from-checkpoint rather than poisoning the run.
  * **Straggler mitigation** — per-step wall times feed an EWMA; steps slower
    than ``straggler_factor`` x EWMA are counted and surfaced (on a real fleet
    this signal feeds the scheduler to evict/replace the slow host; here it is
    the hook + policy, exercised by tests with an injected delay).
  * **Elastic scaling** — checkpoints are topology-free (full logical arrays),
    so `restore(..., shardings=...)` re-places state onto any new mesh; the
    deterministic data pipeline (data/synthetic.py) regenerates any batch from
    (step, shard), so no data is lost or duplicated on reshard.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional


@dataclasses.dataclass
class StragglerStats:
    ewma_s: float = 0.0
    count: int = 0
    slow_steps: int = 0
    last_s: float = 0.0

    def update(self, dt: float, factor: float = 2.0) -> bool:
        self.last_s = dt
        self.count += 1
        if self.ewma_s == 0.0:
            self.ewma_s = dt
            return False
        slow = dt > factor * self.ewma_s
        if slow:
            self.slow_steps += 1
        # straggler steps don't poison the EWMA
        self.ewma_s = 0.9 * self.ewma_s + 0.1 * min(dt, factor * self.ewma_s)
        return slow


class StepFailure(RuntimeError):
    pass


class SupervisedStep:
    """Wrap a step callable with retry + straggler accounting."""

    def __init__(self, fn: Callable[..., Any], max_retries: int = 2,
                 straggler_factor: float = 2.0,
                 on_failure: Optional[Callable[[Exception, int], None]] = None):
        self.fn = fn
        self.max_retries = max_retries
        self.straggler = StragglerStats()
        self.straggler_factor = straggler_factor
        self.on_failure = on_failure
        self.failures = 0

    def __call__(self, *args, **kwargs):
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                out = self.fn(*args, **kwargs)
                _block(out)
                self.straggler.update(time.perf_counter() - t0,
                                      self.straggler_factor)
                return out
            except (RuntimeError, ValueError) as e:  # XLA runtime failures
                self.failures += 1
                attempt += 1
                if self.on_failure:
                    self.on_failure(e, attempt)
                if attempt > self.max_retries:
                    raise StepFailure(
                        f"step failed after {attempt} attempts") from e


def _block(tree):
    import jax
    for l in jax.tree.leaves(tree):
        if hasattr(l, "block_until_ready"):
            l.block_until_ready()
            break
