"""Deterministic synthetic data pipeline.

Produces token batches (and frontend-stub embeddings) as a pure function of
(step, shard) — no host state, so any worker can regenerate any batch after a
restart or an elastic reshard (the data-pipeline side of fault tolerance).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def batch_spec(cfg, batch: int, seq: int):
    """ShapeDtypeStructs for one global batch (used by dry-run + eval_shape)."""
    if cfg.frontend == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                           jnp.dtype(cfg.dtype)),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    if cfg.frontend == "vision":
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq - cfg.n_patches),
                                           jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype)),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


def make_batch(cfg, batch: int, seq: int, step: int = 0, seed: int = 0):
    """Materialize one deterministic batch matching batch_spec."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003)
                                + np.uint64(step))
    if cfg.frontend == "audio":
        return {
            "embeds": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)) * 0.02,
                jnp.dtype(cfg.dtype)),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    if cfg.frontend == "vision":
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq - cfg.n_patches)),
                jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.standard_normal((batch, cfg.n_patches, cfg.d_model)) * 0.02,
                jnp.dtype(cfg.dtype)),
        }
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)}


class SyntheticLoader:
    """Sharded iterator: each data shard regenerates its slice independently."""

    def __init__(self, cfg, global_batch: int, seq: int, seed: int = 0,
                 shard: int = 0, n_shards: int = 1):
        assert global_batch % n_shards == 0
        self.cfg, self.seq, self.seed = cfg, seq, seed
        self.local_batch = global_batch // n_shards
        self.shard = shard

    def batch_at(self, step: int):
        return make_batch(self.cfg, self.local_batch, self.seq, step,
                          seed=self.seed * 131 + self.shard)
