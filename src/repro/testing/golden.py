"""Golden-digest regression registry: the oracle pinned to frozen history.

The conformance harness (:mod:`repro.testing.conformance`) proves the engine
agrees with the *live* sequential oracle — but if the RNG, a model's
arithmetic, or the oracle's processing order drifts, engine and oracle drift
*together* and every "bit-exact" assertion keeps passing.  This module pins
sha256 digests of :func:`repro.core.ref_engine.run_sequential`'s drained
final state — per-object processed counts, the pending ``(dst, seed)``
multiset, and the full object-state pytree (dtype + shape + bytes) — for
every registered workload at two sizes, in ``golden_digests.json`` next to
this file.  Any future bit-exactness claim is thereby checked against frozen
history, not just against whatever the oracle computes today.

Every golden case runs ``dist="dyadic"`` (all floats on the 1/1024 grid with
f32-exact partial sums), so the digests are platform-independent on any
little-endian IEEE-754 machine.

CLI::

  PYTHONPATH=src python -m repro.testing.golden            # verify all
  PYTHONPATH=src python -m repro.testing.golden --regen    # rewrite the JSON

Regeneration is a *deliberate semantics change* — review the diff of
``golden_digests.json`` like any other breaking change (every workload/size
that moved is a workload whose event tree changed).  Regen etiquette:

  * **new workload** → the diff must be *additive-only* (two new
    ``<id>/small`` + ``<id>/medium`` keys); if an existing digest moved,
    the new code leaked into another workload's event tree (shared RNG
    helper, oracle ordering) — fix the leak or justify the break;
  * **intentional semantics change** → regen in the same commit as the
    change, and name the moved workloads in the commit message.

Verification runs in tier-1 (tests/test_golden.py) and as its own CI step,
so drift can't land unreviewed.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Iterator

import numpy as np

from ..core.ref_engine import SequentialResult, run_sequential
from ..workloads.registry import all_workloads, conformance_spec, get_workload

DIGEST_FILE = os.path.join(os.path.dirname(__file__), "golden_digests.json")

#: the second ("medium") size per workload: model_kw overrides applied on top
#: of the workload's CONFORMANCE model_kw, plus the horizon in epochs.  A new
#: workload must add an entry here (and regen) — golden coverage is part of
#: the registry contract, enforced by tests/test_golden.py.
MEDIUM_SIZES: dict[str, tuple[dict, int]] = {
    "phold": (dict(n_objects=48, initial_events=6), 32),
    "phold-hotspot": (dict(n_objects=48, hot_objects=6), 32),
    "queueing": (dict(n_stations=32, n_jobs=128), 32),
    "cluster": (dict(n_nodes=32, n_rings=8), 48),
    "open-queueing": (dict(n_sources=8, n_stage1=8, n_forks=8, n_stage2=8,
                           n_sinks=8), 32),
    "epidemic": (dict(n_patches=48, pop=16, n_seeds=6), 32),
    "wireless": (dict(n_cells=48, hot_cells=8), 32),
}


def golden_cases() -> Iterator[tuple[str, str, dict, int]]:
    """Yield (workload, size, model_kw, n_epochs) for every pinned case."""
    for name in all_workloads():
        spec = conformance_spec(name)
        yield name, "small", spec["model_kw"], spec["n_epochs"]
        if name not in MEDIUM_SIZES:
            raise KeyError(
                f"workload {name!r} has no MEDIUM_SIZES entry — every "
                "registered workload must pin golden digests at two sizes "
                "(add it in repro/testing/golden.py and regen)")
        over, n_epochs = MEDIUM_SIZES[name]
        yield name, "medium", dict(spec["model_kw"], **over), n_epochs


def state_digest(res: SequentialResult) -> str:
    """Canonical sha256 of a sequential run's drained final state.

    Hashes (in fixed order): per-object processed counts (i64), the sorted
    pending ``(dst, seed)`` multiset (u64), then every object's state dict in
    key order with dtype and shape tags — so a silent dtype or layout change
    drifts the digest even when the values happen to collide.
    """
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(
        res.processed_per_object.astype(np.int64)).tobytes())
    pend = res.pending_sorted()
    h.update(np.int64(pend.shape[0]).tobytes())
    h.update(np.ascontiguousarray(pend.astype(np.uint64)).tobytes())
    for st in res.obj_state:
        for k in sorted(st):
            v = np.asarray(st[k])
            h.update(k.encode())
            h.update(str(v.dtype).encode())
            h.update(str(v.shape).encode())
            h.update(np.ascontiguousarray(v).tobytes())
    return h.hexdigest()


def compute_digest(name: str, model_kw: dict, n_epochs: int) -> str:
    """Run the oracle for one golden case and digest its final state."""
    model = get_workload(name, **model_kw)
    res = run_sequential(model, n_epochs, model.params.lookahead)
    if res.total_processed <= 0:
        raise AssertionError(
            f"golden case {name} processed nothing — a digest of an idle "
            "run pins no behavior")
    return state_digest(res)


def load_digests() -> dict[str, str]:
    with open(DIGEST_FILE) as f:
        return json.load(f)


def verify_all() -> list[str]:
    """Check every golden case; return human-readable drift reports."""
    pinned = load_digests()
    problems = []
    seen = set()
    for name, size, model_kw, n_epochs in golden_cases():
        key = f"{name}/{size}"
        seen.add(key)
        got = compute_digest(name, model_kw, n_epochs)
        want = pinned.get(key)
        if want is None:
            problems.append(f"{key}: not pinned (regen to add)")
        elif got != want:
            problems.append(f"{key}: digest drift {want[:12]}… → {got[:12]}…")
    stale = sorted(set(pinned) - seen)
    if stale:
        problems.append(f"stale pinned keys (no matching case): {stale}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regen", action="store_true",
                    help="recompute and rewrite golden_digests.json "
                         "(a deliberate semantics change — review the diff)")
    args = ap.parse_args(argv)

    if args.regen:
        digests = {}
        for name, size, model_kw, n_epochs in golden_cases():
            digests[f"{name}/{size}"] = compute_digest(name, model_kw,
                                                       n_epochs)
            print(f"  {name}/{size}: {digests[f'{name}/{size}'][:16]}…")
        with open(DIGEST_FILE, "w") as f:
            json.dump(dict(sorted(digests.items())), f, indent=1)
            f.write("\n")
        print(f"[golden] wrote {len(digests)} digests to {DIGEST_FILE}")
        return 0

    problems = verify_all()
    for p in problems:
        print(f"DRIFT {p}")
    if problems:
        print("[golden] FAIL — if the change is intentional, regen with "
              "`python -m repro.testing.golden --regen` and review the diff")
        return 1
    print(f"[golden] OK — {len(list(golden_cases()))} cases match pinned "
          "digests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
