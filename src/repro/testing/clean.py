"""The "clean run" contract, in one place (DESP-C++-style validation).

A conservative engine must never silently drop or reorder an event; every
such condition is *counted* in ``Stats``.  The flip side of that contract is
the driver's duty to actually look: a run with nonzero ``fb_overflow`` has
*dropped events* (the fallback spill is truncated after being counted), a
nonzero ``oob_events`` means emissions vanished outside the object space,
and a wall-clock or events/s number from such a run is meaningless.  Both
shipped drivers historically checked only a subset of the counters —
``launch/simulate.py`` ignored ``fb_overflow``/``oob_events`` and
``benchmarks/pdes_perf`` ignored ``fb_overflow``/``route_overflow`` — which
is exactly the bug this module retires: one checker, used by the drivers,
the conformance harness and the tests alike.

Deliberately dependency-free (works on any mapping of counter name → int,
e.g. ``ParsirEngine.totals()`` output or a decoded bench JSON), so the
stdlib-only contexts (CI docs job imports :mod:`repro.testing`; the bench
parent process has no ``src`` on its path) stay importable.
"""
from __future__ import annotations

from typing import Mapping

#: every Stats counter that must be zero after a healthy run.  ``processed``
#: / ``stolen`` / ``rebalances`` / ``migrated`` are activity meters, not
#: error counters, and are deliberately absent.
CLEAN_COUNTERS: tuple[str, ...] = (
    "cal_overflow",          # calendar bucket capacity exceeded
    "fb_overflow",           # fallback spill — events counted then DROPPED
    "route_overflow",        # route buffer misses (events recirculate)
    "late_events",           # causality violations (already-closed epoch)
    "lookahead_violations",  # model emitted ts < ts_in + L
    "oob_events",            # dst outside [0, n_objects) — events dropped
)


def unclean_counters(totals: Mapping[str, int]) -> dict[str, int]:
    """The nonzero must-be-zero counters of ``totals`` (empty == clean)."""
    return {k: int(totals[k]) for k in CLEAN_COUNTERS if int(totals[k]) != 0}


def assert_clean(totals: Mapping[str, int], context: str = "") -> None:
    """Raise AssertionError naming every dirty counter; no-op when clean.

    ``context`` (e.g. ``"simulate"`` or a conformance axis string) prefixes
    the message so sweep failures name their point.
    """
    bad = unclean_counters(totals)
    if bad:
        prefix = f"{context} " if context else ""
        raise AssertionError(
            f"{prefix}UNCLEAN RUN — events were dropped or misordered: "
            f"{bad} (every overflow/causality counter must be 0; resize "
            f"bucket/route/fallback caps or fix the model)")
