"""Shared builders of engine-shaped test inputs.

One definition of the ``extract_sorted``-shaped random slice, consumed by
both the deterministic packer edge tests (tests/test_pipeline.py) and the
hypothesis properties (tests/test_property.py) — so a layout-contract change
(e.g. the dead-slot ``ts=+inf`` sentinel) breaks every consumer at once
instead of leaving a stale copy silently testing the old shape.
"""
from __future__ import annotations

import numpy as np


def random_sorted_slice(cnts, vseed: int = 0, cap: int = 6):
    """A random ``[n_rows, cap]`` calendar slice in extract_sorted layout.

    Row ``o`` holds ``cnts[o]`` live events in its leading columns; dead
    slots carry the canonical ``ts=+inf`` sentinel.  Returns numpy arrays
    ``(ts, seed, payload, cnt, live)`` — callers wrap in jnp as needed.
    (Values are random, not per-row sorted: the packer's contract is
    positional — column r is round r — so sortedness is irrelevant to the
    pack/unpack permutation under test.)
    """
    rng = np.random.default_rng(vseed)
    n_rows = len(cnts)
    cnt = np.asarray(cnts, np.int32).reshape(n_rows)
    live = np.arange(cap)[None, :] < cnt[:, None]
    ts = np.where(live, rng.integers(0, 1024, (n_rows, cap)) / 1024.0,
                  np.inf).astype(np.float32)
    seed = rng.integers(0, 2**32, (n_rows, cap), dtype=np.uint32)
    payload = rng.random((n_rows, cap)).astype(np.float32)
    return ts, seed, payload, cnt, live
