"""Reusable test harnesses (differential conformance against the oracle)
and the drivers' shared "clean run" contract (:mod:`repro.testing.clean`).

This package ``__init__`` must stay importable without jax/numpy — the CI
docs job runs :mod:`repro.testing.docs_check` in a bare environment.
"""
from .clean import CLEAN_COUNTERS, assert_clean, unclean_counters  # noqa: F401

__all__ = ["CLEAN_COUNTERS", "assert_clean", "unclean_counters"]
