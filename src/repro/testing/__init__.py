"""Reusable test harnesses (differential conformance against the oracle)."""
