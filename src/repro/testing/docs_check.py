"""Docs/registry consistency checker (CI docs job + tier-1).

A workload that exists in the registry but is invisible in the README zoo
table, or has no pinned golden digests, is a workload whose contract the
next contributor can't discover — exactly the drift this repo's docs are
supposed to prevent.  This module statically cross-checks (no JAX, no
oracle runs, sub-second):

  1. every ``repro.workloads.registry`` id appears as a ``| `id` |`` row in
     the README workload-zoo table (and the table names no unknown ids);
  2. every registry id is pinned in ``golden_digests.json`` at both sizes
     (``<id>/small`` + ``<id>/medium``; the matching ``MEDIUM_SIZES``
     entry is enforced by tests/test_golden.py, which runs the oracle);
  3. ``docs/writing-a-workload.md`` (the tutorial whose steps, followed
     literally, reproduce a registration) mentions every registry id's
     module-level contract hooks;
  4. the CLI drivers (``repro.launch.simulate`` and
     ``repro.launch.campaign``) expose every orchestration axis — plus,
     for the campaign driver, the sweep axes (seeds/grid/store) — and
     source each choice-typed flag from the sanctioned registry symbol
     (``all_workloads()``, the :mod:`repro.core.pipeline.names` truth
     sets) — a hardcoded choices list is how the simulate driver rotted
     to phold-only while five more workloads shipped.

Deliberately stdlib-only (plus the pure-python registry module): the CI
docs job runs it with no installed dependencies, so nothing here may
import numpy/jax — the golden JSON is read from disk, never through
:mod:`repro.testing.golden`; ``names.py`` is loaded by *file path* (its
package ``__init__`` imports jax) and ``simulate.py`` is AST-parsed, never
imported.

CLI (the CI docs job)::

  PYTHONPATH=src python -m repro.testing.docs_check [--repo-root PATH]

Exit status is the number of problems; ``tests/test_docs.py`` runs the same
checks in tier-1.
"""
from __future__ import annotations

import argparse
import json
import os
import re

from ..workloads.registry import all_workloads

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))

#: hooks the writing-a-workload tutorial must document — the module-level
#: contract every registry entry implements.
TUTORIAL_HOOKS = ("make(", "CONFORMANCE", "process_event_np",
                  "init_object_state_np", "MEDIUM_SIZES", "--regen",
                  "as_emitted", "max_out", "dyadic")

_HEADER_RE = re.compile(r"^\|\s*id\s*\|")
_ROW_RE = re.compile(r"^\|\s*`([a-z0-9-]+)`\s*\|")


def readme_zoo_ids(repo_root: str = REPO_ROOT) -> set[str]:
    """Workload ids named by README's zoo table (``| `id` | ...`` rows).

    Anchored to the table whose header row starts ``| id |`` and stopping
    at its first non-table line — other README tables with backticked
    first columns (scheduler names, config knobs) must not be mistaken
    for workload rows.
    """
    ids: set[str] = set()
    in_table = False
    with open(os.path.join(repo_root, "README.md")) as f:
        for line in f:
            if _HEADER_RE.match(line):
                in_table = True
                continue
            if in_table:
                if not line.startswith("|"):
                    break
                if (m := _ROW_RE.match(line)):
                    ids.add(m.group(1))
    return ids


def check_readme_table(repo_root: str = REPO_ROOT) -> list[str]:
    ids = set(all_workloads())
    in_table = readme_zoo_ids(repo_root)
    problems = []
    for missing in sorted(ids - in_table):
        problems.append(f"README.md zoo table is missing registry workload "
                        f"`{missing}` — add a row (state, events/arity, "
                        f"what it stresses)")
    for stale in sorted(in_table - ids):
        problems.append(f"README.md zoo table names `{stale}`, which is not "
                        f"a registered workload id")
    return problems


def check_golden_coverage(repo_root: str = REPO_ROOT) -> list[str]:
    digest_file = os.path.join(repo_root, "src", "repro", "testing",
                               "golden_digests.json")
    with open(digest_file) as f:
        pinned = set(json.load(f))
    problems = []
    for name in all_workloads():
        for size in ("small", "medium"):
            if f"{name}/{size}" not in pinned:
                problems.append(
                    f"workload `{name}` has no pinned `{name}/{size}` golden "
                    f"digest — add a MEDIUM_SIZES entry if needed and run "
                    f"`python -m repro.testing.golden --regen`")
    return problems


def check_tutorial(repo_root: str = REPO_ROOT) -> list[str]:
    path = os.path.join(repo_root, "docs", "writing-a-workload.md")
    if not os.path.exists(path):
        return ["docs/writing-a-workload.md is missing — the add-a-workload "
                "recipe must live in the repo, not in contributors' heads"]
    with open(path) as f:
        text = f.read()
    return [f"docs/writing-a-workload.md never mentions `{hook}` — the "
            f"tutorial must cover the full registration contract"
            for hook in TUTORIAL_HOOKS if hook not in text]


#: choice-typed CLI flag → the sanctioned symbol its ``choices=``
#: expression must reference (registry truth, never a hardcoded list).
#: Both launch drivers share these axes.
CLI_CHOICE_SOURCES = {
    "--workload": "all_workloads",
    "--scheduler": "SELECTABLE_SCHEDULERS",
    "--route": "ROUTES",
    "--batch-impl": "BATCH_IMPLS",
    "--placement": "PLACEMENTS",
}
SIMULATE_CHOICE_SOURCES = CLI_CHOICE_SOURCES  # back-compat alias

#: every orchestration axis each CLI driver must expose.  The simulate
#: driver additionally owes one ``--opt-*`` flag per speculation knob in
#: ``names.SPECULATION_KNOBS`` — derived at check time (see
#: :func:`_spec_flags`), never listed here, so a new knob that stays
#: CLI-invisible fails the docs job automatically.
SIMULATE_REQUIRED_FLAGS = tuple(CLI_CHOICE_SOURCES) + (
    "--devices", "--rebalance-every", "--model-kw", "--steal", "--drain",
    "--verify")

#: the campaign driver adds the sweep axes on top of the orchestration ones
#: (no --drain/--verify: a campaign is always the fused drain, and each
#: replication's conformance face lives in the harness's --replications).
CAMPAIGN_REQUIRED_FLAGS = tuple(CLI_CHOICE_SOURCES) + (
    "--devices", "--rebalance-every", "--model-kw", "--steal", "--seeds",
    "--grid", "--epochs", "--store", "--require-drained")


def _load_stage_names(repo_root: str):
    """``repro.core.pipeline.names`` loaded by file path — the package
    ``__init__`` imports jax, which the CI docs job doesn't have."""
    import importlib.util
    path = os.path.join(repo_root, "src", "repro", "core", "pipeline",
                        "names.py")
    spec = importlib.util.spec_from_file_location("_parsir_stage_names", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _check_cli(script: str, required: tuple[str, ...],
               repo_root: str = REPO_ROOT) -> list[str]:
    """AST-check one ``repro.launch`` driver: every required flag exposed,
    every choice-typed flag's ``choices=`` sourced from its registry symbol
    (or an exact literal match — hardcoded lists rot as registries grow)."""
    import ast
    path = os.path.join(repo_root, "src", "repro", "launch", script)
    with open(path) as f:
        tree = ast.parse(f.read())
    flags: dict[str, ast.expr | None] = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"
                and node.args and isinstance(node.args[0], ast.Constant)):
            choices = next((kw.value for kw in node.keywords
                            if kw.arg == "choices"), None)
            flags[node.args[0].value] = choices

    problems = []
    for flag in required:
        if flag not in flags:
            problems.append(
                f"repro/launch/{script} exposes no `{flag}` — the CLI "
                f"driver must cover every orchestration axis the engine has")

    names = _load_stage_names(repo_root)
    truth = {"--workload": set(all_workloads()),
             "--scheduler": set(names.SELECTABLE_SCHEDULERS),
             "--route": set(names.ROUTES),
             "--batch-impl": set(names.BATCH_IMPLS),
             "--placement": set(names.PLACEMENTS)}
    for flag, symbol in CLI_CHOICE_SOURCES.items():
        if flag not in flags or flag not in required:
            continue  # missing flags already reported above
        choices = flags[flag]
        if choices is None:
            problems.append(f"{script} `{flag}` has no choices= — drive "
                            f"it from `{symbol}`")
            continue
        referenced = {n.id for n in ast.walk(choices)
                      if isinstance(n, ast.Name)}
        referenced |= {n.attr for n in ast.walk(choices)
                       if isinstance(n, ast.Attribute)}
        if symbol in referenced:
            continue
        try:  # a literal list is tolerable only if it matches truth exactly
            literal = set(ast.literal_eval(choices))
        except (ValueError, SyntaxError):
            literal = None
        if literal != truth[flag]:
            problems.append(
                f"{script} `{flag}` choices are not sourced from "
                f"`{symbol}` (and don't literal-match it) — hardcoded "
                f"choice lists rot as registries grow")
    return problems


def _spec_flags(repo_root: str = REPO_ROOT) -> tuple[str, ...]:
    """``names.SPECULATION_KNOBS`` as CLI flag spellings
    (``opt_window`` → ``--opt-window``)."""
    names = _load_stage_names(repo_root)
    return tuple("--" + knob.replace("_", "-")
                 for knob in names.SPECULATION_KNOBS)


def check_simulate_cli(repo_root: str = REPO_ROOT) -> list[str]:
    required = SIMULATE_REQUIRED_FLAGS + _spec_flags(repo_root)
    return _check_cli("simulate.py", required, repo_root)


def check_campaign_cli(repo_root: str = REPO_ROOT) -> list[str]:
    return _check_cli("campaign.py", CAMPAIGN_REQUIRED_FLAGS, repo_root)


def run_all(repo_root: str = REPO_ROOT) -> list[str]:
    return (check_readme_table(repo_root) + check_golden_coverage(repo_root)
            + check_tutorial(repo_root) + check_simulate_cli(repo_root)
            + check_campaign_cli(repo_root))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--repo-root", default=REPO_ROOT)
    args = ap.parse_args(argv)
    problems = run_all(args.repo_root)
    for p in problems:
        print(f"DOCS DRIFT: {p}")
    if not problems:
        print(f"[docs_check] OK — {len(all_workloads())} workloads "
              f"({', '.join(all_workloads())}) documented, pinned and "
              f"tutorialized")
    return len(problems)


if __name__ == "__main__":
    raise SystemExit(main())
