"""Differential conformance harness: any workload × any engine config.

The engine's correctness contract (engine.py module docstring) is checked
against the sequential numpy oracle (:mod:`repro.core.ref_engine`) in four
parts:

  1. **clean counters** — every overflow/causality/lookahead counter in
     ``Stats`` is zero (a conservative engine never silently drops/reorders;
     the checker is the shared :func:`repro.testing.assert_clean`, the same
     contract the CLI drivers enforce);
  2. **processed count** — equals the oracle's;
  3. **pending multiset** — the (dst, seed) multiset still parked in the
     calendar + fallback equals the oracle's final event heap.  Because all
     model randomness is counter-based, the full event tree is a pure
     function of the initial seeds, so (2) + (3) pin down the processed
     record multiset without the engine keeping a processed log;
  4. **bit-exact state** — for dyadic workloads the final per-object state
     pytree matches the oracle bit-for-bit.

``SWEEP`` names the engine-config axes of the zoo: scheduler (batch | ltf),
routing (allgather | a2a), stealing on/off, per-object batch implementation
(vmap rounds | width-packed tiles | Pallas model kernel — ``packed`` is the
"same bits, different schedule" axis and must stay bit-exact for every
workload, composition and tile width), fractional epoch length, placement
(equal | weighted | adaptive — the oracle knows nothing of devices, so every
packing, including runtime rebalancing with object migration, must reach the
identical drained state), and speculation (``opt_window`` > 0 — windows past
the safe horizon must commit or roll back to exactly the conservative bits,
so the oracle contract is unchanged whether a run speculated or not).  The
checks are emission-arity-agnostic: workloads with fan-out (``max_out > 1``)
and absorption (events that emit nothing — the pending multiset *shrinks*)
run through the identical assertions, since the generalized oracle
(:func:`repro.core.ref_engine.run_sequential`) iterates emitted-event lists.

The module doubles as the multi-device driver (device count is locked at
first JAX init, so multi-device sweeps run in a subprocess)::

  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
    python -m repro.testing.conformance --workload queueing --devices 4 \\
        --configs batch-a2a,steal-allgather,steal-a2a [--drain]

``--drain`` swaps the host-chunked ``run`` for the fused on-device drain
loop (:meth:`ParsirEngine.run_until_drained`) under identical assertions —
the equivalence face of the fused loop, sweepable across every config axis.
"""
from __future__ import annotations

import argparse
from typing import Any

import numpy as np

from ..core.engine import EngineConfig, ParsirEngine
from ..core.ref_engine import SequentialResult, run_sequential
from ..workloads.registry import all_workloads, conformance_spec, get_workload
from .clean import assert_clean

#: named engine-config points of the conformance sweep.  Values are
#: EngineConfig overrides; the two pseudo-keys are handled by the harness:
#: ``epoch_len_frac`` scales epoch_len off the model lookahead (the epoch
#: count is rescaled so the simulated horizon is unchanged), ``batch_impl``
#: = "model" requires the workload's ``supports_batch_impl``.
SWEEP: dict[str, dict] = {
    "batch-allgather": dict(),
    "batch-a2a": dict(route="a2a"),
    "ltf": dict(scheduler="ltf"),
    "steal-allgather": dict(steal=True, steal_cap=2, claim_cap=4),
    "steal-a2a": dict(route="a2a", steal=True, steal_cap=2, claim_cap=4),
    "epoch-fraction": dict(epoch_len_frac=0.5),
    "batch-model": dict(batch_impl="model"),
    # batch_impl axis: the width-packed scheduler must be "same bits,
    # different schedule" — a tiny tile forces many tiles per round (and
    # round-boundary padding) at conformance scale, where the default tile
    # would collapse to one tile per round.
    "batch-packed": dict(batch_impl="packed", pack_tile=4),
    "packed-a2a": dict(route="a2a", batch_impl="packed"),
    "steal-packed": dict(route="a2a", batch_impl="packed", pack_tile=4,
                         steal=True, steal_cap=2, claim_cap=4),
    "packed-adaptive": dict(batch_impl="packed", pack_tile=4,
                            placement="adaptive", rebalance_every=8,
                            migrate_cap=8),
    # placement axis: the same drained state must fall out of every packing
    # of objects onto devices (weighted knapsack, runtime rebalancing, and
    # rebalancing composed with loans) — the oracle knows nothing of devices.
    "weighted": dict(placement="weighted"),
    "adaptive": dict(placement="adaptive", rebalance_every=8, migrate_cap=8),
    "adaptive-a2a": dict(route="a2a", placement="adaptive",
                         rebalance_every=8, migrate_cap=8),
    "steal-adaptive": dict(route="a2a", placement="adaptive",
                           rebalance_every=8, migrate_cap=8,
                           steal=True, steal_cap=2, claim_cap=4),
    # speculation axis (bounded optimism, pipeline/speculate.py): windows up
    # to opt_window epochs past the safe horizon must either commit or roll
    # back to exactly the conservative bits — the oracle knows nothing of
    # speculation, so every assertion below is unchanged.  W=4 needs
    # n_buckets >= 6 (every conformance engine_kw has >= 8).  The default
    # commit locality is per-device (only devices that received a straggler
    # restore their shadow); spec-global pins the PR 9 atomic vote so both
    # verdict modes stay under oracle proof.  Stealing composes under the
    # global vote only (loans execute on the borrower — EngineConfig rejects
    # steal × device-commit fail-fast); adaptive placement composes under
    # either (windows stop short of rebalance firing epochs).  spec-inject
    # drives the deterministic straggler-injection harness: every 2nd window
    # is forced down the rollback path — at ANY device count, D=1 included —
    # and the drained bits must still match the oracle exactly.
    "spec-w1": dict(opt_window=1),
    "spec-w2": dict(opt_window=2),
    "spec-w4": dict(opt_window=4),
    "spec-a2a": dict(route="a2a", opt_window=2),
    "spec-packed-a2a": dict(route="a2a", batch_impl="packed", pack_tile=4,
                            opt_window=2),
    "spec-weighted": dict(placement="weighted", opt_window=2),
    "spec-global": dict(opt_window=2, opt_commit="global"),
    "spec-steal": dict(route="a2a", steal=True, steal_cap=2, claim_cap=4,
                       opt_window=2, opt_commit="global"),
    "spec-adaptive": dict(placement="adaptive", rebalance_every=8,
                          migrate_cap=8, opt_window=2),
    "spec-inject": dict(opt_window=2, inject_straggler_every=2),
}


def engine_pending(eng: ParsirEngine, state) -> np.ndarray:
    """(dst, seed) multiset of events in flight (calendar + fallback), sorted.

    Calendar leading dims concatenate per-device padded rows; the engine maps
    each row to its backing global id (pad rows hold no events by invariant —
    asserted here, since a counted event on a dead row would otherwise be
    silently re-labeled).
    """
    cnt = np.asarray(state.cal.cnt)                  # [D*M, N]
    seed = np.asarray(state.cal.seed)                # [D*M, N, C]
    R, N, C = seed.shape
    gid, live_row = eng.global_row_of(state)
    assert not np.any(cnt[~live_row]), "events parked on a pad row"
    live = np.arange(C)[None, None, :] < cnt[:, :, None]
    obj = np.broadcast_to(gid[:, None, None], live.shape)
    dsts = [obj[live].astype(np.uint64)]
    seeds = [seed[live].astype(np.uint64)]

    fbv = np.asarray(state.fb.events.valid)
    dsts.append(np.asarray(state.fb.events.dst)[fbv].astype(np.uint64))
    seeds.append(np.asarray(state.fb.events.seed)[fbv].astype(np.uint64))

    rec = np.stack([np.concatenate(dsts), np.concatenate(seeds)], axis=1)
    return rec[np.lexsort((rec[:, 1], rec[:, 0]))] if rec.size \
        else rec.reshape(0, 2)


def stack_oracle_state(obj_state: list[dict]) -> dict[str, np.ndarray]:
    """List-of-per-object-dicts (oracle) → dict-of-arrays (engine layout)."""
    keys = obj_state[0].keys()
    return {k: np.stack([np.asarray(s[k]) for s in obj_state])
            for k in keys}


def axes_of(cfg: EngineConfig, n_devices: int) -> str:
    """The sweep coordinates of an engine config, for failure messages.

    Every divergence report must say *which axis point* diverged — a bare
    assert in a workloads × configs × devices sweep is otherwise
    unattributable from the failure line alone.
    """
    impl = cfg.batch_impl
    if impl == "packed":
        impl += f"(tile={cfg.pack_tile})"
    opt = f"opt_window={cfg.opt_window}"
    if cfg.opt_window:
        opt += f"(commit={cfg.opt_commit})"
    return (f"scheduler={cfg.scheduler} batch_impl={impl} "
            f"route={cfg.route} steal={cfg.steal} "
            f"placement={cfg.placement} epoch_len={cfg.epoch_len:g} "
            f"{opt} D={n_devices}")


def _assert_vs_oracle(eng: ParsirEngine, st, tot: dict,
                      ref: SequentialResult, dyadic: bool,
                      ctx: str) -> np.ndarray:
    """The oracle-agreement assertions shared by the scalar and replicated
    conformance faces: processed count, pending (dst, seed) multiset, and —
    for dyadic workloads — bit-exact object state.  Returns the pending
    records."""
    assert tot["processed"] == ref.total_processed, \
        f"{ctx} processed {tot['processed']} != oracle {ref.total_processed}"

    pend = engine_pending(eng, st)
    ref_pend = ref.pending_sorted()
    assert pend.shape == ref_pend.shape, \
        f"{ctx} pending count {pend.shape[0]} != oracle {ref_pend.shape[0]}"
    np.testing.assert_array_equal(
        pend, ref_pend, err_msg=f"{ctx} pending (dst, seed) multiset")

    if dyadic:
        want = stack_oracle_state(ref.obj_state)
        obj = eng.global_object_state(st)
        assert set(want) == set(obj), (ctx, set(want), set(obj))
        for k in want:
            np.testing.assert_array_equal(
                obj[k], want[k], err_msg=f"{ctx} object state [{k}]")
    return pend


def run_conformance(model: Any, overrides: dict, *, n_epochs: int,
                    engine_kw: dict | None = None, mesh=None,
                    dyadic: bool = True,
                    ref: SequentialResult | None = None,
                    label: str = "", drain: bool = False) -> dict:
    """Run ``model`` through the engine under ``overrides`` and assert full
    agreement with the sequential oracle.  Returns a report dict (totals,
    pending count, the oracle result for reuse).  ``label`` (e.g.
    ``"phold/batch-packed"``) prefixes every failure message alongside the
    resolved config axes, so a sweep failure names its diverging point.

    ``drain=True`` runs the horizon through the fused on-device drain loop
    (:meth:`ParsirEngine.run_until_drained` bounded by ``n_epochs``) instead
    of the host-chunked ``run`` — every assertion is unchanged, because a
    drained state is a fixpoint of the step: stopping early at the drain
    epoch leaves exactly the state (and stats) the full horizon would, and
    a non-draining workload runs the identical ``n_epochs`` epochs."""
    overrides = dict(overrides)
    lookahead = model.params.lookahead
    frac = overrides.pop("epoch_len_frac", None)
    kw = dict(lookahead=lookahead)
    kw.update(engine_kw or {})
    kw.update(overrides)
    if frac is not None:
        kw["epoch_len"] = lookahead * frac
        n_epochs = int(round(n_epochs / frac))
    cfg = EngineConfig(**kw)

    eng = ParsirEngine(model, cfg, mesh=mesh)
    ctx = f"[{label + ': ' if label else ''}{axes_of(cfg, eng.D)}]"
    st = (eng.run_until_drained(eng.init(), n_epochs) if drain
          else eng.run(eng.init(), n_epochs))
    tot = eng.totals(st)

    assert_clean(tot, context=ctx)
    if cfg.placement == "adaptive":
        # per-device counters: every device reports each firing, so the sum
        # is (firings × D) — nonzero iff the stage actually ran.
        assert tot["rebalances"] > 0, \
            f"{ctx} adaptive placement never rebalanced: {tot}"

    if ref is None:
        ref = run_sequential(model, n_epochs, cfg.epoch_len)
    pend = _assert_vs_oracle(eng, st, tot, ref, dyadic, ctx)

    return {"totals": tot, "pending": int(pend.shape[0]), "ref": ref,
            "config": kw, "n_epochs": n_epochs}


def check_workload(name: str, config: str, *, mesh=None,
                   ref_cache: dict | None = None,
                   model_overrides: dict | None = None,
                   engine_overrides: dict | None = None,
                   drain: bool = False) -> dict:
    """Conformance-check a registered workload under a named SWEEP config."""
    spec = conformance_spec(name)
    overrides = dict(SWEEP[config])
    if overrides.get("batch_impl") == "model" \
            and not spec["supports_batch_impl"]:
        raise ValueError(f"workload {name} has no process_batch")
    model = get_workload(name, **dict(spec["model_kw"],
                                      **(model_overrides or {})))
    engine_kw = dict(spec["engine_kw"], **(engine_overrides or {}))

    ref = None
    if ref_cache is not None:
        # the oracle run depends on (workload, overrides, horizon), not the
        # engine routing/scheduling config — amortize it across the sweep.
        frac = overrides.get("epoch_len_frac")
        key = (name, spec["n_epochs"], frac,
               tuple(sorted((model_overrides or {}).items())),
               tuple(sorted((engine_overrides or {}).items())))
        ref = ref_cache.get(key)
    report = run_conformance(model, overrides, n_epochs=spec["n_epochs"],
                             engine_kw=engine_kw, mesh=mesh,
                             dyadic=spec["dyadic"], ref=ref,
                             label=f"{name}/{config}", drain=drain)
    if ref_cache is not None:
        ref_cache[key] = report["ref"]
    return report


def check_workload_replicated(name: str, config: str, *, replications: int,
                              mesh=None, rep_shards=None) -> dict:
    """Conformance-check the replication-vmapped fused drain.

    Runs ``replications`` seeds of the workload stacked through ONE
    ``run_replicated_drained`` dispatch (bounded by the workload's
    conformance horizon), then holds **every** replication slice to the full
    scalar contract against its *own* seeded sequential oracle: clean
    counters, processed count, pending multiset, bit-exact dyadic state.
    This is the strongest correctness face of the campaign engine — each
    replication must be indistinguishable from having run alone.

    ``rep_shards=W`` checks the replication-sharded layout instead (mesh
    must be single-device): the R axis is split across W devices and each
    replication steps collective-free inside its shard — same contract,
    same oracles.
    """
    spec = conformance_spec(name)
    overrides = dict(SWEEP[config])
    if overrides.get("batch_impl") == "model" \
            and not spec["supports_batch_impl"]:
        raise ValueError(f"workload {name} has no process_batch")
    model = get_workload(name, **spec["model_kw"])
    n_epochs = spec["n_epochs"]
    lookahead = model.params.lookahead
    frac = overrides.pop("epoch_len_frac", None)
    kw = dict(lookahead=lookahead)
    kw.update(spec["engine_kw"])
    kw.update(overrides)
    if frac is not None:
        kw["epoch_len"] = lookahead * frac
        n_epochs = int(round(n_epochs / frac))
    cfg = EngineConfig(**kw)

    eng = ParsirEngine(model, cfg, mesh=mesh, rep_shards=rep_shards)
    seeds = list(range(replications))
    st = eng.run_replicated_drained(eng.init_replicated(seeds), n_epochs)
    totals = eng.totals_replicated(st)

    processed = []
    for r, seed in enumerate(seeds):
        ctx = (f"[{name}/{config} R={replications} rep={r} seed={seed}: "
               f"{axes_of(cfg, eng.D)}]")
        tot = totals[r]
        assert_clean(tot, context=ctx)
        rep_st = eng.replication(st, r)
        ref = run_sequential(model, n_epochs, cfg.epoch_len, seed=seed)
        _assert_vs_oracle(eng, rep_st, tot, ref, spec["dyadic"], ctx)
        processed.append(tot["processed"])
    return {"processed": processed, "totals": totals, "config": kw,
            "n_epochs": n_epochs}


# ---------------------------------------------------------------------------
# subprocess driver (multi-device sweeps)
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", required=True, choices=all_workloads())
    ap.add_argument("--configs", default="batch-allgather",
                    help="comma-separated SWEEP names, or 'all'")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--expect-stolen", action="store_true",
                    help="assert stats.stolen > 0 summed over steal configs")
    ap.add_argument("--expect-rebalances", type=int, default=0, metavar="N",
                    help="assert every adaptive config fired the rebalance "
                         "stage at least N times")
    ap.add_argument("--expect-rollbacks", action="store_true",
                    help="assert stats.rollbacks > 0 summed over speculation "
                         "(opt_window > 0) configs — the negative path: "
                         "stragglers actually hit the window and the engine "
                         "rolled back, yet every assertion above still held")
    ap.add_argument("--drain", action="store_true",
                    help="run each config through the fused on-device drain "
                         "loop (run_until_drained bounded by the workload's "
                         "n_epochs) instead of host-chunked run — same "
                         "assertions, one XLA dispatch")
    ap.add_argument("--replications", type=int, default=0, metavar="R",
                    help="run R seeds stacked through ONE replication-vmapped"
                         " fused drain (run_replicated_drained) and hold "
                         "every replication to the full scalar contract "
                         "against its own seeded oracle")
    ap.add_argument("--rep-shards", type=int, default=0, metavar="W",
                    help="with --replications: shard the R axis across W "
                         "devices (each replication collective-free on its "
                         "own device) instead of object-sharding — the "
                         "campaign throughput layout, same oracle contract")
    args = ap.parse_args(argv)
    if args.rep_shards and not args.replications:
        ap.error("--rep-shards requires --replications")

    import jax
    from jax.sharding import Mesh
    from ..core.engine import AXIS

    devs = jax.devices()
    assert len(devs) >= args.devices, \
        (f"{len(devs)} devices visible, need {args.devices} — set XLA_FLAGS="
         f"--xla_force_host_platform_device_count={args.devices}")
    mesh = Mesh(np.array(devs[:args.devices]), (AXIS,))

    names = list(SWEEP) if args.configs == "all" \
        else args.configs.split(",")
    unknown = [c for c in names if c not in SWEEP]
    if unknown:
        ap.error(f"unknown config(s) {unknown}; choose from {list(SWEEP)}")
    spec = conformance_spec(args.workload)
    ref_cache: dict = {}
    stolen = 0
    rollbacks = 0
    for config in names:
        if SWEEP[config].get("batch_impl") == "model" \
                and not spec["supports_batch_impl"]:
            print(f"SKIP {args.workload} {config} (no process_batch)")
            continue
        if args.replications:
            # rep-sharding runs each replication whole on one device: the
            # engine's own (object) mesh is single-device, W devices carry
            # the replication axis.
            rmesh = (Mesh(np.array(devs[:1]), (AXIS,)) if args.rep_shards
                     else mesh)
            rep = check_workload_replicated(
                args.workload, config, mesh=rmesh,
                replications=args.replications,
                rep_shards=args.rep_shards or None)
            layout = (f"rep_shards={args.rep_shards}" if args.rep_shards
                      else f"D={args.devices}")
            print(f"OK {args.workload} {config} {layout} "
                  f"R={args.replications} processed={rep['processed']}")
            continue
        report = check_workload(args.workload, config, mesh=mesh,
                                ref_cache=ref_cache, drain=args.drain)
        tot = report["totals"]
        if SWEEP[config].get("steal"):
            stolen += tot["stolen"]
        if SWEEP[config].get("opt_window"):
            rollbacks += tot["rollbacks"]
        if SWEEP[config].get("placement") == "adaptive" \
                and args.expect_rebalances:
            # `rebalances` sums the per-device counters: firings × D.
            fired = tot["rebalances"] // args.devices
            assert fired >= args.expect_rebalances, \
                (f"{config}: rebalance fired {fired} < "
                 f"{args.expect_rebalances} times")
        print(f"OK {args.workload} {config} D={args.devices} "
              f"processed={tot['processed']} pending={report['pending']} "
              f"stolen={tot['stolen']} rebalances={tot['rebalances']} "
              f"migrated={tot['migrated']} rollbacks={tot['rollbacks']} "
              f"speculated={tot['speculated']}")
    if args.expect_stolen:
        assert stolen > 0, "stealing never engaged across steal configs"
    if args.expect_rollbacks:
        assert rollbacks > 0, \
            "no speculation window ever rolled back across opt_window configs"
    print("CONFORMANCE PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
