"""PARSIR engine core: the paper's system, decomposed.

Stable public surface — external code should import from here (or the
submodules listed), not from pipeline internals:

  * :mod:`repro.core.api`       — ``SimModel`` / ``EmittedEvents`` (the model
    contract);
  * :mod:`repro.core.engine`    — ``ParsirEngine`` wrapper + re-exported
    pipeline names (``EngineConfig``, ``EngineState``, ``Stats``, ``AXIS``);
  * :mod:`repro.core.pipeline`  — the stage pipeline (``Scheduler`` /
    ``Router`` / ``StealPolicy`` interfaces + registries) for anyone adding a
    stage implementation;
  * :mod:`repro.core.events`    — ``EventBatch`` + the counter-based RNG;
  * :mod:`repro.core.calendar`, :mod:`repro.core.placement`,
    :mod:`repro.core.stealing` — the data structures the stages ride on;
  * :mod:`repro.core.ref_engine` — the sequential numpy oracle.
"""
from .api import EmittedEvents, SimModel  # noqa: F401
from .engine import (AXIS, EngineConfig, EngineState, ParsirEngine,  # noqa: F401
                     Stats, make_step, zero_stats)
from .events import EventBatch  # noqa: F401
from .placement import Placement, equal_placement, weighted_placement  # noqa: F401
from .ref_engine import SequentialResult, run_sequential  # noqa: F401

__all__ = [
    "AXIS", "EmittedEvents", "EngineConfig", "EngineState", "EventBatch",
    "ParsirEngine", "Placement", "SequentialResult", "SimModel", "Stats",
    "equal_placement", "make_step", "run_sequential", "weighted_placement",
    "zero_stats",
]
