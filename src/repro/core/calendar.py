"""The calendar multi-queue (paper §II-B), as dense device-resident rings.

Per device we keep, for its local objects, a calendar of ``n_buckets`` epoch
buckets with a static per-bucket capacity:

    ts/seed/payload : [n_local, n_buckets, cap]     (compact: slots [0, cnt) live)
    cnt             : [n_local, n_buckets]

Buckets are reused circularly exactly as in the paper: bucket ``e % n_buckets``
holds epoch ``e``; once epoch ``e`` is drained the bucket is cleared and becomes
epoch ``e + n_buckets``.

Insertion is the paper's "per-bucket spinlock" path made *structurally*
conflict-free: incoming events are sorted by (object, bucket), ranks inside each
group are computed with prefix sums, and every event lands at
``cnt[obj, bucket] + rank`` — a lock-free scatter (the TPU replacement for RMW
spinlocks: slot assignment by scan instead of contention).

Extraction in the *current* epoch needs no synchronization at all, mirroring the
paper's lock-free fast path: the SPMD owner is the only reader/writer, and the
lookahead guarantees nobody inserts into the live bucket.

Overflow (bucket capacity exceeded) is counted and returned — never silent; the
conservative engine treats a nonzero count as a hard error at the driver level.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .events import EventBatch


class Calendar(NamedTuple):
    ts: jax.Array       # f32 [n_local, n_buckets, cap]
    seed: jax.Array     # u32 [n_local, n_buckets, cap]
    payload: jax.Array  # f32 [n_local, n_buckets, cap]
    cnt: jax.Array      # i32 [n_local, n_buckets]

    @property
    def n_local(self) -> int:
        return self.ts.shape[0]

    @property
    def n_buckets(self) -> int:
        return self.ts.shape[1]

    @property
    def cap(self) -> int:
        return self.ts.shape[2]


def make_calendar(n_local: int, n_buckets: int, cap: int) -> Calendar:
    return Calendar(
        ts=jnp.full((n_local, n_buckets, cap), jnp.inf, jnp.float32),
        seed=jnp.zeros((n_local, n_buckets, cap), jnp.uint32),
        payload=jnp.zeros((n_local, n_buckets, cap), jnp.float32),
        cnt=jnp.zeros((n_local, n_buckets), jnp.int32),
    )


def group_ranks(key: jax.Array, valid: jax.Array, sentinel: int):
    """Sort events by group key; return (order, sorted_key, rank-in-group).

    rank[i] = position of sorted element i inside its contiguous key group —
    the prefix-sum replacement for fetch_and_add slot assignment.  Shared with
    the width-packer (:mod:`repro.core.pipeline.packing`), whose unpack path
    is the same group-and-rank scatter keyed by object row.
    """
    k = jnp.where(valid, key, sentinel)
    order = jnp.argsort(k, stable=True)
    ks = k[order]
    idx = jnp.arange(k.shape[0], dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    start_idx = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank = idx - start_idx
    return order, ks, rank


def insert(cal: Calendar, local_idx: jax.Array, epoch: jax.Array,
           ts: jax.Array, seed: jax.Array, payload: jax.Array,
           valid: jax.Array):
    """Insert a flat batch of events destined to local objects.

    epoch must already be within the calendar horizon (caller splits fallback).
    Returns (calendar, n_overflow).
    """
    n_local, n_buckets, cap = cal.ts.shape
    bucket = (epoch % n_buckets).astype(jnp.int32)
    key = local_idx * n_buckets + bucket
    sentinel = n_local * n_buckets
    order, ks, rank = group_ranks(key, valid, sentinel)

    ts_s = ts[order]
    seed_s = seed[order]
    pay_s = payload[order]
    valid_s = ks < sentinel

    base = cal.cnt.reshape(-1)[jnp.where(valid_s, ks, 0)]
    slot = base + rank
    ok = valid_s & (slot < cap)
    n_overflow = jnp.sum((valid_s & ~ok).astype(jnp.int32))

    flat = jnp.where(ok, ks * cap + slot, n_local * n_buckets * cap)
    new_ts = cal.ts.reshape(-1).at[flat].set(ts_s, mode="drop").reshape(cal.ts.shape)
    new_seed = cal.seed.reshape(-1).at[flat].set(seed_s, mode="drop").reshape(cal.seed.shape)
    new_pay = cal.payload.reshape(-1).at[flat].set(pay_s, mode="drop").reshape(cal.payload.shape)

    cnt_flat = cal.cnt.reshape(-1).at[jnp.where(ok, ks, sentinel)].add(
        jnp.ones_like(ks, jnp.int32), mode="drop")
    new_cnt = cnt_flat.reshape(cal.cnt.shape)
    return Calendar(new_ts, new_seed, new_pay, new_cnt), n_overflow


def bucket_occupancy(cal: Calendar, epoch: jax.Array) -> jax.Array:
    """Per-row event count of the bucket holding ``epoch`` — no drain.

    The occupancy vector the width-packer's schedule is built from (round
    ``r`` of the batch loop touches exactly the rows with ``occupancy > r``),
    exposed separately so diagnostics (:meth:`ParsirEngine.occupancy`) and
    tests can quantify the padded-grid vs packed work without extracting.
    """
    b = (epoch % cal.n_buckets).astype(jnp.int32)
    return jax.lax.dynamic_index_in_dim(cal.cnt, b, axis=1, keepdims=False)


def extract_sorted(cal: Calendar, epoch: jax.Array):
    """Drain the bucket for ``epoch``: per-object events sorted by (ts, seed).

    Returns (calendar-with-cleared-bucket, ts, seed, payload, cnt_b) where the
    event arrays are [n_local, cap] with invalid slots at ts=+inf.  This is the
    paper's lock-free current-epoch extraction — plus the batch ordering that
    per-object causality requires.
    """
    n_local, n_buckets, cap = cal.ts.shape
    b = (epoch % n_buckets).astype(jnp.int32)
    ts = jax.lax.dynamic_index_in_dim(cal.ts, b, axis=1, keepdims=False)
    seed = jax.lax.dynamic_index_in_dim(cal.seed, b, axis=1, keepdims=False)
    pay = jax.lax.dynamic_index_in_dim(cal.payload, b, axis=1, keepdims=False)
    cnt_b = jax.lax.dynamic_index_in_dim(cal.cnt, b, axis=1, keepdims=False)

    live = jnp.arange(cap, dtype=jnp.int32)[None, :] < cnt_b[:, None]
    ts = jnp.where(live, ts, jnp.inf)

    # lexicographic (ts, seed): two stable argsorts composed.
    p1 = jnp.argsort(seed, axis=1, stable=True)
    ts1 = jnp.take_along_axis(ts, p1, axis=1)
    p2 = jnp.argsort(ts1, axis=1, stable=True)
    order = jnp.take_along_axis(p1, p2, axis=1)

    ts = jnp.take_along_axis(ts, order, axis=1)
    seed = jnp.take_along_axis(seed, order, axis=1)
    pay = jnp.take_along_axis(pay, order, axis=1)

    # clear the bucket for reuse (epoch + n_buckets).
    new_cnt = jax.lax.dynamic_update_index_in_dim(
        cal.cnt, jnp.zeros((n_local,), jnp.int32), b, axis=1)
    new_ts = jax.lax.dynamic_update_index_in_dim(
        cal.ts, jnp.full((n_local, cap), jnp.inf, jnp.float32), b, axis=1)
    return cal._replace(ts=new_ts, cnt=new_cnt), ts, seed, pay, cnt_b


# ---------------------------------------------------------------------------
# bulk row movement (adaptive-placement migration, paper §II-C)
# ---------------------------------------------------------------------------

def take_rows(cal: Calendar, idx: jax.Array) -> Calendar:
    """Gather whole per-object calendar rows (all buckets, all slots).

    Bucket indices are absolute-epoch modulo ``n_buckets`` — identical on
    every device — so a row's bucket contents stay valid wherever the row
    lands.  This is the bulk-extract half of object migration: the rebalance
    stage ships rows wholesale instead of flattening events through the
    bounded route path (no capacity to overflow, nothing to drop).
    """
    return Calendar(cal.ts[idx], cal.seed[idx], cal.payload[idx],
                    cal.cnt[idx])


def put_rows(cal: Calendar, idx: jax.Array, rows: Calendar,
             mask: jax.Array) -> Calendar:
    """Scatter whole calendar rows into local slots where ``mask`` holds.

    The reinsert half of migration: receivers overwrite the slot wholesale
    (the migrated row replaces whatever the slot held — callers guarantee the
    slot was vacated).  Masked-off rows are dropped via an out-of-range index.
    """
    safe = jnp.where(mask, idx, cal.n_local)
    put = lambda dstf, srcf: dstf.at[safe].set(srcf, mode="drop")
    return Calendar(put(cal.ts, rows.ts), put(cal.seed, rows.seed),
                    put(cal.payload, rows.payload), put(cal.cnt, rows.cnt))


def clear_rows(cal: Calendar, dead: jax.Array) -> Calendar:
    """Deaden rows where ``dead`` holds: zero counts, +inf timestamps.

    Used after a rebalance shifts a device's range: slots no longer backing a
    live object must never contribute events (extraction and the pending-
    multiset readers both key off ``cnt``/``ts``).
    """
    cnt = jnp.where(dead[:, None], 0, cal.cnt)
    ts = jnp.where(dead[:, None, None], jnp.inf, cal.ts)
    return cal._replace(ts=ts, cnt=cnt)


def take_buckets(cal: Calendar, first_epoch, n: int) -> Calendar:
    """Snapshot ``n`` consecutive epoch buckets starting at ``first_epoch``.

    The shadow-copy half of the speculation stage (pipeline/speculate.py):
    the returned Calendar holds the window's buckets only — O(W) rows per
    object, not the whole ring — in window order (bucket axis index w holds
    epoch ``first_epoch + w``).  The complement of :func:`take_rows`: rows
    select objects, this selects *epochs*.
    """
    idx = (first_epoch + jnp.arange(n, dtype=jnp.int32)) % cal.n_buckets
    return Calendar(cal.ts[:, idx], cal.seed[:, idx], cal.payload[:, idx],
                    cal.cnt[:, idx])


def put_buckets(cal: Calendar, first_epoch, shadow: Calendar) -> Calendar:
    """Restore a :func:`take_buckets` snapshot wholesale (rollback).

    Every slot of the window's buckets is overwritten from the shadow —
    speculative insertions vanish, speculative extractions reappear — so the
    calendar is bit-restored to the snapshot point for those epochs.
    Buckets outside the window are untouched — the disjointness that makes
    the restore *local*: under per-device commit (``opt_commit='device'``)
    only violated devices run it, and a device's rollback can never disturb
    epochs (its own or anyone else's) outside its window.  Property-tested
    in tests/test_property.py: take ∘ damage ∘ put is the identity on the
    window, ring wrap-around included.
    """
    n = shadow.ts.shape[1]
    idx = (first_epoch + jnp.arange(n, dtype=jnp.int32)) % cal.n_buckets
    return Calendar(cal.ts.at[:, idx].set(shadow.ts),
                    cal.seed.at[:, idx].set(shadow.seed),
                    cal.payload.at[:, idx].set(shadow.payload),
                    cal.cnt.at[:, idx].set(shadow.cnt))


class Fallback(NamedTuple):
    """The per-thread TLS fallback list (paper §II-B) → per-device buffer.

    Events whose epoch lies beyond the calendar horizon (or that missed the
    route-capacity this epoch) park here with their *global* dst and are
    re-offered every epoch close, exactly like the paper drains TLS lists as
    the circular calendar advances.
    """

    events: EventBatch  # flat [cap]

    @property
    def cap(self) -> int:
        return self.events.capacity


def make_fallback(cap: int) -> Fallback:
    from .events import empty_batch
    return Fallback(empty_batch(cap))


def fallback_put(fb: Fallback, new: EventBatch):
    """Append valid events of ``new`` into free slots of the fallback buffer.

    Returns (fallback, n_overflow).  Compaction keeps live events in front.
    """
    from .events import compact, concat_batches
    merged = compact(concat_batches(fb.events, new))
    cap = fb.cap
    keep = EventBatch(*(x[..., :cap] for x in merged))
    spill = merged.valid[..., cap:]
    return Fallback(keep), jnp.sum(spill.astype(jnp.int32))
