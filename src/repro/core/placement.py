"""Object → device placement (the paper's NUMA knapsack, §II-A / §II-C).

PARSIR packs simulation-object identifiers into per-NUMA-node knapsacks and
keeps ``min[i]``/``max[i]`` per node.  We keep exactly that: contiguous global
id ranges per mesh device, expressed as a boundaries vector, with a weighted
variant that balances expected event rates (the knapsack objective).  The
owner lookup used by event routing is a ``searchsorted`` over the boundaries —
the SPMD analogue of the paper's range check against min/max.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np


class Placement(NamedTuple):
    """Static contiguous placement of n_objects over n_devices.

    boundaries: i32[n_devices + 1]; device d owns [boundaries[d], boundaries[d+1]).
    n_local_max: max objects on any device (static pad for per-device arrays).
    """

    boundaries: np.ndarray
    n_objects: int
    n_devices: int
    n_local_max: int

    def owner_np(self, dst: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.boundaries, dst, side="right").astype(np.int32) - 1

    def owner(self, dst):
        b = jnp.asarray(self.boundaries)
        return jnp.searchsorted(b, dst, side="right").astype(jnp.int32) - 1

    def local_index(self, dst, owner):
        starts = jnp.asarray(self.boundaries)[owner]
        return dst - starts

    def range_of(self, d: int) -> tuple[int, int]:
        return int(self.boundaries[d]), int(self.boundaries[d + 1])

    def counts(self) -> np.ndarray:
        return np.diff(self.boundaries).astype(np.int32)


def equal_placement(n_objects: int, n_devices: int) -> Placement:
    """Uniform knapsack: near-equal contiguous ranges."""
    boundaries = np.round(np.linspace(0, n_objects, n_devices + 1)).astype(np.int64)
    n_local_max = int(np.max(np.diff(boundaries)))
    return Placement(boundaries, n_objects, n_devices, n_local_max)


def weighted_placement(weights: Sequence[float], n_devices: int) -> Placement:
    """Knapsack by expected per-object load: split the prefix-sum of weights at
    equal-mass quantiles, keeping ranges contiguous (the paper's packing is also
    contiguous-by-id)."""
    w = np.asarray(weights, dtype=np.float64)
    n_objects = w.shape[0]
    cum = np.concatenate([[0.0], np.cumsum(w)])
    total = cum[-1]
    targets = total * np.arange(1, n_devices) / n_devices
    cuts = np.searchsorted(cum, targets, side="left")
    boundaries = np.concatenate([[0], cuts, [n_objects]]).astype(np.int64)
    # ensure monotone non-decreasing (degenerate weights)
    boundaries = np.maximum.accumulate(boundaries)
    n_local_max = int(np.max(np.diff(boundaries)))
    return Placement(boundaries, n_objects, n_devices, max(n_local_max, 1))
