"""Object → device placement (the paper's NUMA knapsack, §II-A / §II-C).

PARSIR packs simulation-object identifiers into per-NUMA-node knapsacks and
keeps ``min[i]``/``max[i]`` per node.  We keep exactly that: contiguous global
id ranges per mesh device, expressed as a boundaries vector, with a weighted
variant that balances expected event rates (the knapsack objective).  The
owner lookup used by event routing is a ``searchsorted`` over the boundaries —
the SPMD analogue of the paper's range check against min/max.

Boundaries are allowed to be *dynamic*: the engine stores the live boundaries
vector in ``EngineState`` and rebuilds a traced :class:`Placement` each step
via :meth:`Placement.with_boundaries`, which is what lets the adaptive
rebalance stage (:mod:`repro.core.pipeline.rebalance`) move the cuts at epoch
boundaries without retracing.  The static fields (``n_objects``,
``n_devices``, ``n_local_max``) never change after engine construction —
``n_local_max`` is the per-device row *pad*: every device materializes exactly
that many object rows, with rows beyond its live count inert (zero calendar
counts, never receiving events).

``owner`` itself gives garbage for out-of-range ids (-1 below, the last
device at/above the top edge) — callers must mask ``dst`` against
``[0, n_objects)`` first.  The engine counts such events in
``stats.oob_events`` and drops them at the producer, never silently
delivering them onto an edge device's wrong local slot.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np


class Placement(NamedTuple):
    """Contiguous placement of n_objects over n_devices.

    boundaries: i32[n_devices + 1]; device d owns [boundaries[d], boundaries[d+1]).
                May be a numpy array (static placement) or a traced jax array
                (runtime placement inside the engine step).
    n_local_max: row pad — objects materialized per device (static).
    """

    boundaries: np.ndarray
    n_objects: int
    n_devices: int
    n_local_max: int

    def owner_np(self, dst: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.boundaries, dst, side="right").astype(np.int32) - 1

    def owner(self, dst):
        b = jnp.asarray(self.boundaries)
        return jnp.searchsorted(b, dst, side="right").astype(jnp.int32) - 1

    def local_index(self, dst, owner):
        starts = jnp.asarray(self.boundaries)[owner]
        return dst - starts

    def range_of(self, d: int) -> tuple[int, int]:
        return int(self.boundaries[d]), int(self.boundaries[d + 1])

    def counts(self) -> np.ndarray:
        return np.diff(self.boundaries).astype(np.int32)

    def with_boundaries(self, boundaries) -> "Placement":
        """Same static shape info, live (possibly traced) boundaries."""
        return self._replace(boundaries=boundaries)

    def padded(self, n_local_max: int) -> "Placement":
        """Widen the per-device row pad (adaptive placement headroom)."""
        if n_local_max < self.n_local_max:
            raise ValueError(f"pad {n_local_max} < required {self.n_local_max}")
        return self._replace(n_local_max=n_local_max)

    def padded_gids(self) -> np.ndarray:
        """Global object id of every padded row, [n_devices * n_local_max].

        Rows beyond a device's live count repeat its last owned id (or 0 for
        an empty device) so padding state is always valid model state.
        """
        out = []
        for d in range(self.n_devices):
            lo, hi = self.range_of(d)
            g = np.arange(lo, hi, dtype=np.int64)
            fill = g[-1] if g.size else 0
            out.append(np.concatenate(
                [g, np.full(self.n_local_max - g.size, fill, np.int64)]))
        return np.concatenate(out)


def equal_placement(n_objects: int, n_devices: int) -> Placement:
    """Uniform knapsack: near-equal contiguous ranges."""
    boundaries = np.round(np.linspace(0, n_objects, n_devices + 1)).astype(np.int64)
    n_local_max = int(np.max(np.diff(boundaries)))
    return Placement(boundaries, n_objects, n_devices, n_local_max)


def weighted_placement(weights: Sequence[float], n_devices: int) -> Placement:
    """Knapsack by expected per-object load: split the prefix-sum of weights at
    equal-mass quantiles, keeping ranges contiguous (the paper's packing is also
    contiguous-by-id).

    Degenerate weights (non-finite, negative, or summing to ~zero — where the
    quantile targets collapse and every cut lands on an edge) fall back to the
    equal split instead of piling all objects onto one device.  The returned
    ``n_local_max`` is the true maximum range size, not papered over.
    """
    w = np.asarray(weights, dtype=np.float64)
    n_objects = w.shape[0]
    total = float(np.sum(w))
    if (not np.isfinite(total) or np.any(~np.isfinite(w)) or np.any(w < 0)
            or total <= 1e-12 * max(1, n_objects)):
        return equal_placement(n_objects, n_devices)
    cum = np.concatenate([[0.0], np.cumsum(w)])
    targets = total * np.arange(1, n_devices) / n_devices
    cuts = np.searchsorted(cum, targets, side="left")
    boundaries = np.concatenate([[0], cuts, [n_objects]]).astype(np.int64)
    # ensure monotone non-decreasing (repeated cuts on zero-weight runs)
    boundaries = np.maximum.accumulate(boundaries)
    n_local_max = int(np.max(np.diff(boundaries)))
    return Placement(boundaries, n_objects, n_devices, n_local_max)
