"""Delivery stage: insertion at the owner (paper §II-B, pipeline stage 5).

Owners insert routed in-horizon events into calendar buckets (conflict-free
scatter) and park beyond-horizon events in the fallback buffer.  Capacity
overflow and late (already-closed-epoch) arrivals are counted, never silent.
Delivery is the same code for the per-epoch step and the initial-event ingest
(``init=True`` widens the window to include the current epoch).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..calendar import Calendar, Fallback, fallback_put, insert
from ..events import EventBatch
from ..placement import Placement
from .base import epoch_of


def deliver(cal: Calendar, fb: Fallback, batch: EventBatch, cur, dev,
            placement: Placement, cfg, init: bool):
    """Insert my in-horizon events; park my beyond-horizon events in fallback."""
    N = cfg.n_buckets
    epochs = epoch_of(batch.ts, cfg.epoch_len)
    boundaries = jnp.asarray(placement.boundaries, jnp.int32)
    owner = placement.owner(batch.dst)
    mine = batch.valid & (owner == dev)
    lo = jnp.int32(0) if init else cur + 1
    hi = cur + (N - 1 if init else N)
    insertable = mine & (epochs >= lo) & (epochs <= hi)
    beyond = mine & (epochs > hi)
    late = jnp.sum((mine & (epochs < lo)).astype(jnp.int32))

    local_idx = jnp.clip(batch.dst - boundaries[dev], 0, cal.n_local - 1)
    cal, cal_ovf = insert(cal, local_idx, epochs, batch.ts, batch.seed,
                          batch.payload, insertable)
    fb, fb_ovf = fallback_put(fb, EventBatch(batch.dst, batch.ts, batch.seed,
                                             batch.payload, beyond))
    return cal, fb, cal_ovf, fb_ovf, late
