"""Delivery stage: insertion at the owner (paper §II-B, pipeline stage 5).

Owners insert routed in-horizon events into calendar buckets (conflict-free
scatter) and park beyond-horizon events in the fallback buffer.  Capacity
overflow, late (already-closed-epoch) arrivals and out-of-range destinations
are counted, never silent.  Delivery is the same code for the per-epoch step
and the initial-event ingest (``init=True`` widens the window to include the
current epoch).

Out-of-range ``dst`` (< 0 or >= n_objects) would otherwise be *silently
mangled*: ``Placement.owner``'s searchsorted lands ``dst >= n_objects`` on
the last device and the local-index clip would then insert the event into the
wrong object's calendar.  Such events are excluded from ``mine`` and counted
with a **replication-aware reduction**: an oob dst has no well-defined owner,
so when the incoming batch is replicated across devices (the initial ingest,
or an ``allgather``-routed exchange) only device 0 counts it — the per-device
``Stats`` are summed globally, so counting everywhere would report D× the
truth — while a per-device-distinct batch (``a2a``-routed slices) is counted
where it lands, since each corrupt event exists on exactly one device.
(Counting only on device 0 unconditionally, as this stage once did,
*undercounted* any deliver-side oob arriving via a2a on devices 1..D-1.)
Drivers treat a nonzero ``stats.oob_events`` as a hard error, like overflow.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..calendar import Calendar, Fallback, fallback_put, insert
from ..events import EventBatch
from ..placement import Placement
from .base import epoch_of


def deliver(cal: Calendar, fb: Fallback, batch: EventBatch, cur, dev,
            placement: Placement, cfg, init: bool, replicated: bool = True):
    """Insert my in-horizon events; park my beyond-horizon events in fallback.

    ``replicated`` declares whether ``batch`` is identical on every device
    (broadcast exchange / initial ingest — oob counted once, on device 0) or
    a per-device-distinct slice (a2a — oob counted where it lands; see the
    module docstring).  Returns (cal, fb, n_cal_overflow, n_fb_overflow,
    n_late, n_oob).
    """
    N = cfg.n_buckets
    epochs = epoch_of(batch.ts, cfg.epoch_len)
    boundaries = jnp.asarray(placement.boundaries, jnp.int32)
    oob = batch.valid & ((batch.dst < 0)
                         | (batch.dst >= placement.n_objects))
    n_oob_local = jnp.sum(oob.astype(jnp.int32))
    n_oob = jnp.where(dev == 0, n_oob_local, 0) if replicated else n_oob_local
    owner = placement.owner(batch.dst)
    mine = batch.valid & ~oob & (owner == dev)
    lo = jnp.int32(0) if init else cur + 1
    hi = cur + (N - 1 if init else N)
    insertable = mine & (epochs >= lo) & (epochs <= hi)
    beyond = mine & (epochs > hi)
    late = jnp.sum((mine & (epochs < lo)).astype(jnp.int32))

    local_idx = jnp.clip(batch.dst - boundaries[dev], 0, cal.n_local - 1)
    cal, cal_ovf = insert(cal, local_idx, epochs, batch.ts, batch.seed,
                          batch.payload, insertable)
    fb, fb_ovf = fallback_put(fb, EventBatch(batch.dst, batch.ts, batch.seed,
                                             batch.payload, beyond))
    return cal, fb, cal_ovf, fb_ovf, late, n_oob
