"""Scheduler stage implementations (paper §II-A).

``batch``       — PARSIR's per-object batch rounds: round r applies the r-th
                  (ts, seed)-ordered event of every object in parallel (vmap),
                  keeping each object's state register/VMEM-hot across its
                  whole batch.
``batch-model`` — same schedule, but the whole per-object batch goes through
                  the model's own ``process_batch`` kernel (e.g. the Pallas
                  event-apply kernel) instead of the vmap rounds loop.
``ltf``         — strict lowest-timestamp-first interleaving across objects
                  (ROOT-Sim/USE-style), one event at a time — same results,
                  no batch locality.  The Fig-5 analogue comparison point.

All schedulers honor the generalized emission contract: each processed event
may emit 0..``model.max_out`` events; emitted ``valid`` masks flow through
unchanged (an absorbing model simply emits an all-invalid row).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..api import SimModel
from ..events import EventBatch
from .base import Scheduler, register_scheduler


def process_batch_rounds(model: SimModel, obj: Any, ts_s, seed_s, pay_s,
                         cnt_b, lookahead: float):
    """Round r applies the r-th (ts,seed)-ordered event of every object.

    A plain function (not just a method) because the loan-stealing policy
    reuses it for the claimed-batch augmented processing pass.
    """
    n_rows, C = ts_s.shape
    mo = model.max_out
    out0 = EventBatch(
        dst=jnp.zeros((C, n_rows, mo), jnp.int32),
        ts=jnp.full((C, n_rows, mo), jnp.inf, jnp.float32),
        seed=jnp.zeros((C, n_rows, mo), jnp.uint32),
        payload=jnp.zeros((C, n_rows, mo), jnp.float32),
        valid=jnp.zeros((C, n_rows, mo), bool),
    )

    def body(r, carry):
        obj, out, lv = carry
        ets = jax.lax.dynamic_index_in_dim(ts_s, r, axis=1, keepdims=False)
        eseed = jax.lax.dynamic_index_in_dim(seed_s, r, axis=1, keepdims=False)
        epay = jax.lax.dynamic_index_in_dim(pay_s, r, axis=1, keepdims=False)
        m = r < cnt_b
        new_obj, emitted = jax.vmap(model.process_event)(obj, ets, eseed, epay)

        def sel(n, o):
            mm = m.reshape(m.shape + (1,) * (n.ndim - 1))
            return jnp.where(mm, n, o)

        obj = jax.tree.map(sel, new_obj, obj)
        ev_valid = emitted.valid & m[:, None]
        lv = lv + jnp.sum((ev_valid
                           & (emitted.ts < ets[:, None] + jnp.float32(lookahead))
                           ).astype(jnp.int32))
        out = EventBatch(
            dst=out.dst.at[r].set(emitted.dst),
            ts=out.ts.at[r].set(jnp.where(ev_valid, emitted.ts, jnp.inf)),
            seed=out.seed.at[r].set(emitted.seed),
            payload=out.payload.at[r].set(emitted.payload),
            valid=out.valid.at[r].set(ev_valid),
        )
        return obj, out, lv

    max_r = jnp.max(cnt_b) if n_rows else jnp.int32(0)
    obj, out, lv = jax.lax.fori_loop(
        0, max_r, body, (obj, out0, jnp.int32(0)))
    flat = EventBatch(*(x.reshape(-1) for x in out))
    return obj, flat, lv


@register_scheduler("batch")
class BatchRoundsScheduler(Scheduler):
    """PARSIR per-object batch processing via the vmap rounds loop."""

    def process(self, model, obj, ts_s, seed_s, pay_s, cnt_b, lookahead):
        return process_batch_rounds(model, obj, ts_s, seed_s, pay_s, cnt_b,
                                    lookahead)


@register_scheduler("batch-model")
class ModelKernelScheduler(Scheduler):
    """Whole per-object batches through the model's own kernel
    (``batch_impl='model'``, e.g. Pallas event-apply)."""

    def validate(self, model, cfg):
        if not hasattr(model, "process_batch"):
            raise ValueError("batch_impl='model' needs model.process_batch")

    def process(self, model, obj, ts_s, seed_s, pay_s, cnt_b, lookahead):
        return model.process_batch(obj, ts_s, seed_s, pay_s, cnt_b, lookahead)


@register_scheduler("ltf")
class LtfScheduler(Scheduler):
    """Strict lowest-timestamp-first interleaving across objects."""

    def process(self, model, obj, ts_s, seed_s, pay_s, cnt_b, lookahead):
        n_rows, C = ts_s.shape
        mo = model.max_out
        rows = jnp.broadcast_to(jnp.arange(n_rows, dtype=jnp.int32)[:, None],
                                (n_rows, C)).reshape(-1)
        live = (jnp.arange(C, dtype=jnp.int32)[None, :]
                < cnt_b[:, None]).reshape(-1)
        ts_f = jnp.where(live, ts_s.reshape(-1), jnp.inf)
        seed_f, pay_f = seed_s.reshape(-1), pay_s.reshape(-1)

        p1 = jnp.argsort(seed_f, stable=True)
        p2 = jnp.argsort(ts_f[p1], stable=True)
        order = p1[p2]
        ts_f, seed_f, pay_f = ts_f[order], seed_f[order], pay_f[order]
        rows, live = rows[order], live[order]

        K = n_rows * C
        out0 = EventBatch(
            dst=jnp.zeros((K, mo), jnp.int32),
            ts=jnp.full((K, mo), jnp.inf, jnp.float32),
            seed=jnp.zeros((K, mo), jnp.uint32),
            payload=jnp.zeros((K, mo), jnp.float32),
            valid=jnp.zeros((K, mo), bool),
        )

        def body(i, carry):
            obj, out, lv = carry
            row = rows[i]
            st = jax.tree.map(lambda l: l[row], obj)
            new_st, emitted = model.process_event(st, ts_f[i], seed_f[i],
                                                  pay_f[i])
            obj = jax.tree.map(lambda l, n: l.at[row].set(n), obj, new_st)
            lv = lv + jnp.sum((emitted.valid
                               & (emitted.ts < ts_f[i] + jnp.float32(lookahead))
                               ).astype(jnp.int32))
            out = EventBatch(
                dst=out.dst.at[i].set(emitted.dst),
                ts=out.ts.at[i].set(jnp.where(emitted.valid, emitted.ts,
                                              jnp.inf)),
                seed=out.seed.at[i].set(emitted.seed),
                payload=out.payload.at[i].set(emitted.payload),
                valid=out.valid.at[i].set(emitted.valid),
            )
            return obj, out, lv

        total = jnp.sum(cnt_b)
        obj, out, lv = jax.lax.fori_loop(0, total, body,
                                         (obj, out0, jnp.int32(0)))
        flat = EventBatch(*(x.reshape(-1) for x in out))
        return obj, flat, lv
