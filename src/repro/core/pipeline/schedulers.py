"""Scheduler stage implementations (paper §II-A).

``batch``        — PARSIR's per-object batch rounds: round r applies the r-th
                   (ts, seed)-ordered event of every object in parallel
                   (vmap), keeping each object's state register/VMEM-hot
                   across its whole batch.
``batch-packed`` — the same schedule width-packed: the occupied slots of the
                   epoch slice are compacted round-major into a dense work
                   list (:mod:`repro.core.pipeline.packing`) and processed in
                   fixed-size vmap tiles with a per-tile state gather /
                   scatter-back.  Same bits, different schedule: epoch cost
                   scales with the events actually present instead of
                   ``max batch depth × padded row width``.
``batch-model``  — same schedule, but the whole per-object batch goes through
                   the model's own ``process_batch`` kernel (e.g. the Pallas
                   event-apply kernel) instead of the vmap rounds loop.
``ltf``          — strict lowest-timestamp-first interleaving across objects
                   (ROOT-Sim/USE-style), one event at a time — same results,
                   no batch locality.  The Fig-5 analogue comparison point.

Schedulers receive the live :class:`~repro.core.pipeline.config.EngineConfig`
(``process(model, cfg, obj, …)``) so implementation knobs — ``lookahead``,
the packer's ``pack_tile`` — stay on the config instead of leaking into the
stage interface one positional argument at a time.

All schedulers honor the generalized emission contract: each processed event
may emit 0..``model.max_out`` events; emitted ``valid`` masks flow through
unchanged (an absorbing model simply emits an all-invalid row).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..api import SimModel
from ..events import EventBatch
from .base import Scheduler, register_scheduler
from .packing import pack_slice


def process_batch_rounds(model: SimModel, obj: Any, ts_s, seed_s, pay_s,
                         cnt_b, lookahead: float):
    """Round r applies the r-th (ts,seed)-ordered event of every object.

    A plain function (not just a method) because the loan-stealing policy
    reuses it for the claimed-batch augmented processing pass.
    """
    n_rows, C = ts_s.shape
    mo = model.max_out
    out0 = EventBatch(
        dst=jnp.zeros((C, n_rows, mo), jnp.int32),
        ts=jnp.full((C, n_rows, mo), jnp.inf, jnp.float32),
        seed=jnp.zeros((C, n_rows, mo), jnp.uint32),
        payload=jnp.zeros((C, n_rows, mo), jnp.float32),
        valid=jnp.zeros((C, n_rows, mo), bool),
    )

    def body(r, carry):
        obj, out, lv = carry
        ets = jax.lax.dynamic_index_in_dim(ts_s, r, axis=1, keepdims=False)
        eseed = jax.lax.dynamic_index_in_dim(seed_s, r, axis=1, keepdims=False)
        epay = jax.lax.dynamic_index_in_dim(pay_s, r, axis=1, keepdims=False)
        m = r < cnt_b
        new_obj, emitted = jax.vmap(model.process_event)(obj, ets, eseed, epay)

        def sel(n, o):
            mm = m.reshape(m.shape + (1,) * (n.ndim - 1))
            return jnp.where(mm, n, o)

        obj = jax.tree.map(sel, new_obj, obj)
        ev_valid = emitted.valid & m[:, None]
        lv = lv + jnp.sum((ev_valid
                           & (emitted.ts < ets[:, None] + jnp.float32(lookahead))
                           ).astype(jnp.int32))
        out = EventBatch(
            dst=out.dst.at[r].set(emitted.dst),
            ts=out.ts.at[r].set(jnp.where(ev_valid, emitted.ts, jnp.inf)),
            seed=out.seed.at[r].set(emitted.seed),
            payload=out.payload.at[r].set(emitted.payload),
            valid=out.valid.at[r].set(ev_valid),
        )
        return obj, out, lv

    # `initial=0` handles the zero-rows slice uniformly — jnp.max on an empty
    # array would raise at trace time, and a Python shape branch here used to
    # leave the n_rows == 0 path untested.
    max_r = jnp.max(cnt_b, initial=0).astype(jnp.int32)
    obj, out, lv = jax.lax.fori_loop(
        0, max_r, body, (obj, out0, jnp.int32(0)))
    flat = EventBatch(*(x.reshape(-1) for x in out))
    return obj, flat, lv


def process_batch_packed(model: SimModel, obj: Any, ts_s, seed_s, pay_s,
                         cnt_b, lookahead: float, tile: int):
    """Width-packed batch rounds: dense tiles over the occupied slots.

    The slice is packed round-major (see :mod:`.packing`): tiles never span a
    round boundary, so each tile holds at most one event per object and the
    per-tile gather → vmap(process_event) → scatter-back is conflict-free,
    while an object's rounds land in strictly increasing tiles (the scatter
    carries its state forward).  Identical per-event inputs in identical
    intra-object order ⇒ bit-identical results to ``batch``.
    """
    n_rows, C = ts_s.shape
    mo = model.max_out
    packed = pack_slice(ts_s, seed_s, pay_s, cnt_b, tile)
    k_pad, T = packed.ts.shape[0], packed.tile
    out0 = EventBatch(
        dst=jnp.zeros((k_pad, mo), jnp.int32),
        ts=jnp.full((k_pad, mo), jnp.inf, jnp.float32),
        seed=jnp.zeros((k_pad, mo), jnp.uint32),
        payload=jnp.zeros((k_pad, mo), jnp.float32),
        valid=jnp.zeros((k_pad, mo), bool),
    )
    if k_pad == 0:
        return obj, EventBatch(*(x.reshape(-1) for x in out0)), jnp.int32(0)

    def body(t, carry):
        obj, out, lv = carry
        start = t * T
        sl = lambda a: jax.lax.dynamic_slice(a, (start,), (T,))
        rows, vvalid = sl(packed.row), sl(packed.valid)
        vts, vseed, vpay = sl(packed.ts), sl(packed.seed), sl(packed.payload)

        st = jax.tree.map(lambda l: l[jnp.clip(rows, 0, n_rows - 1)], obj)
        new_st, emitted = jax.vmap(model.process_event)(st, vts, vseed, vpay)

        # dead slots scatter to the n_rows sentinel and drop.
        scat_rows = jnp.where(vvalid, rows, n_rows)
        obj = jax.tree.map(
            lambda l, n: l.at[scat_rows].set(n, mode="drop"), obj, new_st)

        ev_valid = emitted.valid & vvalid[:, None]
        lv = lv + jnp.sum((ev_valid
                           & (emitted.ts < vts[:, None] + jnp.float32(lookahead))
                           ).astype(jnp.int32))
        upd = lambda dst, src: jax.lax.dynamic_update_slice(dst, src,
                                                            (start, 0))
        out = EventBatch(
            dst=upd(out.dst, emitted.dst),
            ts=upd(out.ts, jnp.where(ev_valid, emitted.ts, jnp.inf)),
            seed=upd(out.seed, emitted.seed),
            payload=upd(out.payload, emitted.payload),
            valid=upd(out.valid, ev_valid),
        )
        return obj, out, lv

    obj, out, lv = jax.lax.fori_loop(
        0, packed.n_tiles, body, (obj, out0, jnp.int32(0)))
    flat = EventBatch(*(x.reshape(-1) for x in out))
    return obj, flat, lv


@register_scheduler("batch")
class BatchRoundsScheduler(Scheduler):
    """PARSIR per-object batch processing via the vmap rounds loop."""

    def process(self, model, cfg, obj, ts_s, seed_s, pay_s, cnt_b):
        return process_batch_rounds(model, obj, ts_s, seed_s, pay_s, cnt_b,
                                    cfg.lookahead)


@register_scheduler("batch-packed")
class PackedBatchScheduler(Scheduler):
    """Width-packed batch rounds (``batch_impl='packed'``): process only the
    occupied event slots, in ``pack_tile``-wide vmap tiles."""

    def process(self, model, cfg, obj, ts_s, seed_s, pay_s, cnt_b):
        return process_batch_packed(model, obj, ts_s, seed_s, pay_s, cnt_b,
                                    cfg.lookahead, cfg.pack_tile)


@register_scheduler("batch-model")
class ModelKernelScheduler(Scheduler):
    """Whole per-object batches through the model's own kernel
    (``batch_impl='model'``, e.g. Pallas event-apply)."""

    def validate(self, model, cfg):
        if not hasattr(model, "process_batch"):
            raise ValueError("batch_impl='model' needs model.process_batch")

    def process(self, model, cfg, obj, ts_s, seed_s, pay_s, cnt_b):
        return model.process_batch(obj, ts_s, seed_s, pay_s, cnt_b,
                                   cfg.lookahead)


@register_scheduler("ltf")
class LtfScheduler(Scheduler):
    """Strict lowest-timestamp-first interleaving across objects."""

    def process(self, model, cfg, obj, ts_s, seed_s, pay_s, cnt_b):
        lookahead = cfg.lookahead
        n_rows, C = ts_s.shape
        mo = model.max_out
        rows = jnp.broadcast_to(jnp.arange(n_rows, dtype=jnp.int32)[:, None],
                                (n_rows, C)).reshape(-1)
        live = (jnp.arange(C, dtype=jnp.int32)[None, :]
                < cnt_b[:, None]).reshape(-1)
        ts_f = jnp.where(live, ts_s.reshape(-1), jnp.inf)
        seed_f, pay_f = seed_s.reshape(-1), pay_s.reshape(-1)

        p1 = jnp.argsort(seed_f, stable=True)
        p2 = jnp.argsort(ts_f[p1], stable=True)
        order = p1[p2]
        ts_f, seed_f, pay_f = ts_f[order], seed_f[order], pay_f[order]
        rows, live = rows[order], live[order]

        K = n_rows * C
        out0 = EventBatch(
            dst=jnp.zeros((K, mo), jnp.int32),
            ts=jnp.full((K, mo), jnp.inf, jnp.float32),
            seed=jnp.zeros((K, mo), jnp.uint32),
            payload=jnp.zeros((K, mo), jnp.float32),
            valid=jnp.zeros((K, mo), bool),
        )

        def body(i, carry):
            obj, out, lv = carry
            row = rows[i]
            st = jax.tree.map(lambda l: l[row], obj)
            new_st, emitted = model.process_event(st, ts_f[i], seed_f[i],
                                                  pay_f[i])
            obj = jax.tree.map(lambda l, n: l.at[row].set(n), obj, new_st)
            lv = lv + jnp.sum((emitted.valid
                               & (emitted.ts < ts_f[i] + jnp.float32(lookahead))
                               ).astype(jnp.int32))
            out = EventBatch(
                dst=out.dst.at[i].set(emitted.dst),
                ts=out.ts.at[i].set(jnp.where(emitted.valid, emitted.ts,
                                              jnp.inf)),
                seed=out.seed.at[i].set(emitted.seed),
                payload=out.payload.at[i].set(emitted.payload),
                valid=out.valid.at[i].set(emitted.valid),
            )
            return obj, out, lv

        total = jnp.sum(cnt_b)
        obj, out, lv = jax.lax.fori_loop(0, total, body,
                                         (obj, out0, jnp.int32(0)))
        flat = EventBatch(*(x.reshape(-1) for x in out))
        return obj, flat, lv
