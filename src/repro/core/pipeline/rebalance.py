"""RebalancePolicy stage implementations (paper §II-A / §II-C).

``none``     — the boundaries set at engine construction are final.
``adaptive`` — every ``rebalance_every`` epochs, recompute the contiguous
               placement boundaries from *measured* per-object processed
               counts (the knapsack objective of ``weighted_placement``, fed
               by runtime load instead of a static hint) and migrate moved
               objects — state row + whole calendar rows — to their new
               owners.

Mechanics, all static-shape and counted-never-silent:

  * the per-device ``load`` vector (accumulated batch sizes since the last
    firing) is all-gathered and scattered into a global per-object load
    array — the SPMD stand-in for the paper's per-NUMA-node counters;
  * new boundaries are the equal-mass quantile cuts of that load's prefix
    sum, computed *replicated* (every device derives the identical vector
    from the identical gathered inputs — no coordinator);
  * each boundary's shift is clamped to ``migrate_cap // 2`` and each
    device's range to ``n_local_max`` rows, so the set of rows leaving any
    device is bounded by ``migrate_cap`` *by construction* — migration can
    never overflow, so nothing needs dropping;
  * leaving rows (a prefix and/or suffix of the device's contiguous range)
    are published — object state plus whole calendar rows
    (:func:`repro.core.calendar.take_rows`) — through an ``all_gather``,
    mirroring the loan path's exchange; staying rows shift local slots by
    a gather-roll; receivers scatter claimed rows into their new slots
    (:func:`~repro.core.calendar.put_rows`) and vacated slots are deadened
    (:func:`~repro.core.calendar.clear_rows`).

Calendar buckets are ring-indexed by absolute epoch (``epoch % n_buckets``),
identical on every device, so migrated rows' bucket contents stay valid as-is.
Fallback entries carry *global* destinations and are re-offered through the
normal routers every epoch, so they re-home themselves after the boundary
move — the routers are the migration path for everything not yet delivered.

The stage fires between process and route, so the epoch's fresh emissions are
routed against the new boundaries immediately.

Composition with speculation (``opt_window > 0``, pipeline/speculate.py):
sound under BOTH commit modes, because the speculation stage only ever lets
this stage fire at the *safe* epoch — the window is clamped so no
speculative sub-epoch lands on or leaps over a firing epoch (a migration
moves calendar rows wholesale, which no shadow copy could restore on a
remote device).  Every firing therefore runs exactly as it would in the
conservative step: replicated boundary computation, committed state, no
shadow to reconcile.  The boundaries/load carried through the window commit
only on the window's own verdict.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..calendar import Calendar, clear_rows, put_rows, take_rows
from .base import AXIS, RebalancePolicy, register_rebalancer


@register_rebalancer("none")
class NoRebalance(RebalancePolicy):
    """Static placement: boundaries never move."""

    def rebalance(self, cfg, placement, dev, cur, bounds, load, cal, obj):
        return bounds, load, cal, obj, jnp.int32(0), jnp.int32(0)


def _quantile_boundaries(obj_load, bounds, D, M, O, shift_cap):
    """Replicated new-boundaries computation: equal-mass cuts, clamped.

    Clamps keep every boundary within ``shift_cap`` of its old position and
    every device's range within the static row pad ``M`` while staying
    feasible (the remaining devices can always hold the remaining objects) —
    provable by induction from the old boundaries' own feasibility.
    """
    w = obj_load.astype(jnp.float32)
    cum = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(w)])
    total = cum[-1]
    targets = total * jnp.arange(1, D, dtype=jnp.float32) / D
    cuts = jnp.searchsorted(cum, targets, side="left").astype(jnp.int32)
    desired = jnp.concatenate([jnp.zeros((1,), jnp.int32), cuts,
                               jnp.full((1,), O, jnp.int32)])
    nb = [jnp.int32(0)]
    for d in range(1, D):
        lo = jnp.maximum(jnp.maximum(nb[d - 1], bounds[d] - shift_cap),
                         jnp.int32(O - (D - d) * M))
        hi = jnp.minimum(jnp.minimum(nb[d - 1] + M, bounds[d] + shift_cap),
                         jnp.int32(d * M))
        nb.append(jnp.clip(desired[d], lo, hi))
    nb.append(jnp.int32(O))
    new_b = jnp.stack(nb)
    # an idle window (no events processed anywhere) carries no signal.
    return jnp.where(total > 0, new_b, bounds)


@register_rebalancer("adaptive")
class AdaptiveRebalance(RebalancePolicy):
    """Epoch-boundary boundary recomputation + object migration."""

    def rebalance(self, cfg, placement, dev, cur, bounds, load, cal, obj):
        D = placement.n_devices
        M = placement.n_local_max
        O = placement.n_objects
        R = cfg.rebalance_every
        shift_cap = jnp.int32(cfg.migrate_cap // 2)
        K = 2 * (cfg.migrate_cap // 2)      # max rows leaving one device

        fire = (cur + 1) % R == 0

        def skip(args):
            bounds, load, cal, obj = args
            return bounds, load, cal, obj, jnp.int32(0), jnp.int32(0)

        def do(args):
            bounds, load, cal, obj = args
            starts, cnts = bounds[:-1], bounds[1:] - bounds[:-1]

            # -- measured global per-object load (replicated) ----------------
            all_load = jax.lax.all_gather(load, AXIS)          # [D, M]
            d_idx = jnp.arange(D * M, dtype=jnp.int32) // M
            i_idx = jnp.arange(D * M, dtype=jnp.int32) % M
            gid_all = starts[d_idx] + i_idx
            row_live = i_idx < cnts[d_idx]
            obj_load = jnp.zeros((O,), jnp.int32).at[
                jnp.where(row_live, gid_all, O)].add(
                    all_load.reshape(-1), mode="drop")

            new_b = _quantile_boundaries(obj_load, bounds, D, M, O, shift_cap)

            # -- publish leaving rows (prefix + suffix of my old range) ------
            old_start, old_end = bounds[dev], bounds[dev + 1]
            new_start, new_end = new_b[dev], new_b[dev + 1]
            old_cnt = old_end - old_start
            a = jnp.clip(new_start - old_start, 0, old_cnt)    # leave front
            c = jnp.clip(old_end - new_end, 0, old_cnt - a)    # leave back
            k = jnp.arange(K, dtype=jnp.int32)
            pub_slot = jnp.where(k < a, k, old_cnt - c + (k - a))
            pub_valid = k < a + c
            pub_slot = jnp.clip(pub_slot, 0, M - 1)
            pub = {
                "obj": jax.tree.map(lambda l: l[pub_slot], obj),
                "cal": take_rows(cal, pub_slot),
                "gid": jnp.where(pub_valid, old_start + pub_slot, O),
            }
            pub_g = jax.tree.map(lambda x: jax.lax.all_gather(x, AXIS), pub)

            # -- staying rows shift local slots by the boundary delta --------
            shift = new_start - old_start
            src = (jnp.arange(M, dtype=jnp.int32) + shift) % M
            obj2 = jax.tree.map(lambda l: l[src], obj)
            cal2 = take_rows(cal, src)
            gid_new = new_start + jnp.arange(M, dtype=jnp.int32)
            new_cnt = new_end - new_start
            stay = ((jnp.arange(M, dtype=jnp.int32) < new_cnt)
                    & (gid_new >= old_start) & (gid_new < old_end))

            # -- claim migrated rows now inside my new range -----------------
            flat = lambda l: l.reshape((D * K,) + l.shape[2:])
            rgid = flat(pub_g["gid"])
            rown = jnp.searchsorted(new_b, rgid, side="right"
                                    ).astype(jnp.int32) - 1
            rmine = (rgid < O) & (rown == dev)
            rslot = jnp.clip(rgid - new_start, 0, M - 1)
            obj3 = jax.tree.map(
                lambda l, r: l.at[jnp.where(rmine, rslot, M)].set(
                    r, mode="drop"),
                obj2, jax.tree.map(flat, pub_g["obj"]))
            cal3 = put_rows(cal2, rslot, jax.tree.map(flat, pub_g["cal"]),
                            rmine)

            received = jnp.zeros((M,), bool).at[
                jnp.where(rmine, rslot, M)].set(True, mode="drop")
            cal4 = clear_rows(cal3, ~(stay | received))

            n_recv = jnp.sum(rmine.astype(jnp.int32))
            return (new_b, jnp.zeros_like(load), cal4, obj3, n_recv,
                    jnp.int32(1))

        return jax.lax.cond(fire, do, skip, (bounds, load, cal, obj))
