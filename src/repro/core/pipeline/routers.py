"""Router stage implementations (paper §II-B).

``allgather`` — every device broadcasts its route buffer to everyone and each
                owner filters at delivery: the direct SPMD transliteration of
                PARSIR's shared-memory "any thread enqueues anywhere".
``a2a``       — the optimized pairwise exchange: per-destination-device
                sub-buffers of ``route_cap // D`` events through
                ``all_to_all``, D× less traffic than the broadcast.

Both degrade to an identity exchange on a single device; a2a additionally
falls back to global (first-come) selection there, since per-pair sub-buffers
only exist with a real exchange.  Selection never drops events silently:
whatever misses the route capacity is counted *and* handed back to the
caller's fallback buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..events import EventBatch, compact_mask, truncate
from .base import AXIS, Router, register_router


def _select_send_global(prod: EventBatch, eligible, cfg):
    """First-come selection: the first route_cap eligible events are sent."""
    rank = jnp.cumsum(eligible.astype(jnp.int32)) - 1
    send = eligible & (rank < cfg.route_cap)
    ovf = jnp.sum((eligible & ~send).astype(jnp.int32))
    buf = truncate(compact_mask(prod, send), cfg.route_cap)
    return buf, send, ovf


@register_router("allgather")
class AllGatherRouter(Router):
    """Broadcast exchange — every device sees every route buffer."""

    replicated = True   # exchange() output is identical on every device

    def select_send(self, prod, eligible, placement, cfg):
        return _select_send_global(prod, eligible, cfg)

    def exchange(self, buf, placement, cfg):
        if placement.n_devices == 1:
            return buf
        g = jax.tree.map(lambda x: jax.lax.all_gather(x, AXIS), buf)
        return EventBatch(*(x.reshape(-1) for x in g))

    def sender_ids(self, placement, cfg):
        # broadcast layout: D stacked route buffers, route_cap slots each.
        D = placement.n_devices
        if D == 1:
            return jnp.zeros((cfg.route_cap,), jnp.int32)
        return jnp.repeat(jnp.arange(D, dtype=jnp.int32), cfg.route_cap)


@register_router("a2a")
class AllToAllRouter(Router):
    """Pairwise exchange with per-destination-device sub-buffers."""

    replicated = False  # each device receives a distinct routed slice

    def validate(self, cfg, placement):
        cfg.validate(placement.n_devices)

    def select_send(self, prod, eligible, placement, cfg):
        D = placement.n_devices
        if D == 1:
            return _select_send_global(prod, eligible, cfg)
        pair_cap = cfg.route_cap // D
        owner = placement.owner(prod.dst)
        key = jnp.where(eligible, owner, D)
        order = jnp.argsort(key, stable=True)
        ks = key[order]
        idx = jnp.arange(ks.shape[0], dtype=jnp.int32)
        is_start = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
        start_idx = jax.lax.associative_scan(jnp.maximum,
                                             jnp.where(is_start, idx, 0))
        rank = idx - start_idx
        ok = (ks < D) & (rank < pair_cap)
        ovf = jnp.sum(((ks < D) & ~ok).astype(jnp.int32))

        slot = jnp.where(ok, ks * pair_cap + rank, D * pair_cap)

        def put(field, fill, dtype):
            out = jnp.full((D * pair_cap,), fill, dtype)
            return out.at[slot].set(field[order], mode="drop")

        valid = jnp.zeros((D * pair_cap,), bool).at[slot].set(True,
                                                              mode="drop")
        buf = EventBatch(
            dst=put(prod.dst, 0, jnp.int32),
            ts=put(prod.ts, jnp.inf, jnp.float32),
            seed=put(prod.seed, 0, jnp.uint32),
            payload=put(prod.payload, 0.0, jnp.float32),
            valid=valid,
        )
        # sent mask back in original event order
        send = jnp.zeros_like(eligible).at[order].set(ok)
        return buf, send, ovf

    def exchange(self, buf, placement, cfg):
        D = placement.n_devices
        if D == 1:
            return buf
        pair_cap = cfg.route_cap // D
        shaped = jax.tree.map(lambda x: x.reshape(D, pair_cap), buf)
        recv = jax.tree.map(
            lambda x: jax.lax.all_to_all(x, AXIS, split_axis=0, concat_axis=0,
                                         tiled=True), shaped)
        return EventBatch(*(x.reshape(-1) for x in recv))

    def sender_ids(self, placement, cfg):
        # after all_to_all, dim 0 of the [D, pair_cap] view is the source.
        D = placement.n_devices
        if D == 1:
            return jnp.zeros((cfg.route_cap,), jnp.int32)
        return jnp.repeat(jnp.arange(D, dtype=jnp.int32), cfg.route_cap // D)
