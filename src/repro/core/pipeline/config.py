"""EngineConfig: the stage-selection + capacity record of the pipeline.

Everything an engine build needs to know that isn't the model or the mesh.
Stage names (``scheduler``, ``route``) are registry keys resolved by
:mod:`repro.core.pipeline.base`; unknown names and degenerate capacities fail
at *construction* time.  The one check that needs the device count —
``route_cap >= n_devices`` for a2a, without which the per-pair sub-buffers
would be zero-sized and every event would silently spill to fallback — lives
in :meth:`EngineConfig.validate` and is invoked by the engine (and the a2a
router) as soon as the mesh is known.

Bit-exactness contract: **no field of this record is allowed to change
simulation semantics.**  Every legal configuration — any scheduler, batch
implementation, router, stealing, placement, epoch length or capacity —
must drive the engine to the sequential oracle's drained state bit-for-bit
(the conformance SWEEP is the cross-product proof).  Capacities bound
*buffers*, never behavior: overflow is counted in ``Stats`` and the
affected events recirculate; nothing is silently dropped or reordered.
"""
from __future__ import annotations

import dataclasses

from .names import PLACEMENTS


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """The engine's complete configuration surface, one knob per field.

    Units, defaults and valid ranges (validated in ``__post_init__`` /
    :meth:`validate` — degenerate values fail at construction, never
    mid-run):

    ======================  =============================================
    field                   units · default · valid range
    ======================  =============================================
    ``lookahead``           simulated-time units; required; > 0.  The
                            model's conservative bound L — every emitted
                            event satisfies ``ts_out >= ts_in + L``.
    ``epoch_len``           simulated-time units; default ``lookahead``;
                            (0, lookahead].  Window width of one epoch;
                            smaller = more, emptier epochs.
    ``n_buckets``           count; default 8; >= 1 and > the maximum
                            epochs-ahead any model emission can land
                            (``ceil((L + max_draw) / epoch_len)``) or
                            inserts overflow (counted).
    ``bucket_cap``          events per (object, bucket); default 128;
                            >= 1.  Depth of one calendar cell — size for
                            the hottest object's per-epoch batch.
    ``route_cap``           events per device per epoch; default 4096;
                            >= 1; for a2a also >= n_devices and divisible
                            by it (per-pair sub-buffer = route_cap / D).
    ``fallback_cap``        events per device; default 4096; >= 1.
                            Park-list for events the exchange couldn't
                            carry; they retry next epoch.
    ``route``               registry name; default ``"allgather"``;
                            {allgather, a2a} (+ user-registered).
    ``scheduler``           registry name; default ``"batch"``; {batch,
                            ltf} ∪ user-registered, excluding the internal
                            batch-family names (selected via batch_impl).
    ``batch_impl``          default ``"rounds"``; {rounds, packed, model};
                            only with ``scheduler="batch"``.  A *schedule*
                            choice: identical bits by contract.
    ``pack_tile``           rows; default 64; >= 1 (clamped to the local
                            row count).  packed's vmap tile width —
                            schedule-only, any value yields identical bits.
    ``steal``               bool; default False.  Epoch-granular object
                            loans; requires the batch scheduler family
                            with batch_impl in {rounds, packed}.
    ``steal_cap``           loans per donor per epoch; default 4; >= 1
                            when stealing (0 would silently never steal).
    ``claim_cap``           loans per receiver per epoch; default 4;
                            >= 1 when stealing.
    ``placement``           default ``"equal"``; {equal, weighted,
                            adaptive} (paper §II-A/§II-C knapsacks).
    ``rebalance_every``     epochs; default 0; >= 1 iff adaptive (0 would
                            silently never fire; nonzero otherwise is
                            rejected as dead config).
    ``migrate_cap``         calendar/state rows per device per rebalance;
                            default 16; >= 2 when adaptive.  Boundary
                            shifts are clamped to ``migrate_cap // 2`` —
                            migration traffic is bounded by construction.
    ``placement_slack``     ratio; default 2.0; >= 1.0 when adaptive.
                            Static per-device row pad over the equal
                            split — headroom for boundaries to skew
                            without reallocation.
    ``opt_window``          epochs; default 0 (strictly conservative);
                            >= 0.  W > 0 speculates up to W epochs past
                            the safe horizon against a shadow copy and
                            rolls violated windows back on stragglers
                            (Time Warp lite — schedule-only, same bits).
                            Requires n_buckets >= W + 2.  Composes with
                            placement='adaptive' (windows are clamped to
                            stop short of rebalance firing epochs) and
                            with steal=True (which requires
                            opt_commit='global' — loans execute on the
                            borrower, so the verdict must be atomic).
    ``opt_stage_cap``       events per device; default 0 → route_cap;
                            >= 1 when speculating (0 otherwise).
                            Staging buffer for speculative emissions
                            that may not be published yet (remote dst,
                            or beyond the shadow window); overflow
                            aborts the window — counted as a rollback,
                            never as a drop.
    ``opt_commit``          default ``"device"``; {device, global}; only
                            with opt_window > 0.  Commit locality:
                            'device' rolls back only devices that
                            received a straggler (horizon-guarded, see
                            pipeline/speculate.py); 'global' is the
                            atomic all-or-nothing vote.  Schedule-only:
                            identical bits either way.
    ``opt_adaptive``        bool; default False; only with opt_window
                            > 0.  Host-side controller retunes the live
                            window between drain dispatches from the
                            observed rollbacks/spec_commits ratio
                            (opt_window becomes the cap).  Schedule-
                            only: any W sequence yields the same bits.
    ``inject_straggler_every``  windows; default 0 (off); only with
                            opt_window > 0.  Test-only determinism
                            harness: every n-th window is forced down
                            the rollback path on every device.  Only
                            the ``rollbacks`` activity meter (never a
                            clean counter) observes it.
    ======================  =============================================
    """

    lookahead: float                 # model lookahead L
    epoch_len: float | None = None   # defaults to L; may be a fraction of it
    n_buckets: int = 8               # N — calendar epochs in flight
    bucket_cap: int = 128            # events per (object, bucket)
    route_cap: int = 4096            # outgoing events per device per epoch
    fallback_cap: int = 4096         # per-device fallback list capacity
    route: str = "allgather"         # Router registry key (allgather | a2a)
    scheduler: str = "batch"         # Scheduler registry key (batch | ltf | …)
    batch_impl: str = "rounds"       # rounds (vmap grid) | packed (width-
    #                                  packed tiles) | model (Pallas kernel)
    pack_tile: int = 64              # packed: vmap tile width (clamped to the
    #                                  local row count; schedule-only — any
    #                                  tile yields identical bits)
    steal: bool = False
    steal_cap: int = 4               # loans a donor may publish per epoch
    claim_cap: int = 4               # loans a receiver may claim per epoch
    placement: str = "equal"         # equal | weighted | adaptive (§II-A/C)
    rebalance_every: int = 0         # adaptive: epochs between rebalances
    migrate_cap: int = 16            # adaptive: max rows a device publishes
    #                                  per rebalance (boundary shift <= cap/2)
    placement_slack: float = 2.0     # adaptive: per-device row pad factor
    #                                  over the equal split (headroom for the
    #                                  boundaries to skew)
    opt_window: int = 0              # speculation window W (0 = conservative)
    opt_stage_cap: int = 0           # speculative-emission staging buffer
    #                                  (0 → route_cap when speculating)
    opt_commit: str = "device"       # commit locality: device (only violated
    #                                  devices roll back) | global (atomic)
    opt_adaptive: bool = False       # host-side live-W controller (W = cap)
    inject_straggler_every: int = 0  # test-only: force every n-th window to
    #                                  abort (0 = off; deterministic rollback
    #                                  coverage at any device count)

    def __post_init__(self):
        if self.lookahead <= 0:
            raise ValueError(f"lookahead must be > 0 (the conservative bound "
                             f"L), got {self.lookahead}")
        el = self.epoch_len if self.epoch_len is not None else self.lookahead
        if el <= 0:
            raise ValueError(f"epoch_len must be > 0, got {el}")
        if el > self.lookahead + 1e-9:
            raise ValueError("epoch_len must be <= lookahead (conservative)")
        object.__setattr__(self, "epoch_len", el)

        caps = ["n_buckets", "bucket_cap", "route_cap", "fallback_cap",
                "pack_tile"]
        if self.steal:
            caps += ["steal_cap", "claim_cap"]  # 0 would silently never steal
        for cap in caps:
            if getattr(self, cap) < 1:
                raise ValueError(f"{cap} must be >= 1, got {getattr(self, cap)}")
        if self.placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {self.placement!r} "
                             f"(choose from {list(PLACEMENTS)})")
        if self.placement == "adaptive":
            if self.rebalance_every < 1:
                raise ValueError(
                    "placement='adaptive' needs rebalance_every >= 1 — with "
                    f"{self.rebalance_every} the rebalance stage would "
                    "silently never fire")
            if self.migrate_cap < 2:
                raise ValueError(
                    f"migrate_cap must be >= 2 (one row each way per "
                    f"rebalance), got {self.migrate_cap}")
            if self.placement_slack < 1.0:
                raise ValueError(
                    f"placement_slack must be >= 1.0, got "
                    f"{self.placement_slack}")
        elif self.rebalance_every:
            raise ValueError(
                f"rebalance_every={self.rebalance_every} only applies to "
                f"placement='adaptive' (got placement={self.placement!r}) — "
                "it would silently do nothing")

        if self.opt_window < 0:
            raise ValueError(
                f"opt_window must be >= 0, got {self.opt_window}")
        if self.opt_commit not in ("device", "global"):
            raise ValueError(
                f"unknown opt_commit {self.opt_commit!r} "
                "(choose from ['device', 'global'])")
        if self.opt_window > 0:
            if self.steal and self.opt_commit != "global":
                # a loaned batch executes on the borrower: a split verdict
                # could commit the borrower's staged loan emissions while
                # the aborting owner re-executes the loaned batch — the
                # same events delivered twice.  The atomic vote keeps loan
                # effects and their rollback in lockstep.
                raise ValueError(
                    "steal=True with opt_window > 0 requires "
                    "opt_commit='global' — loaned batches execute on the "
                    "borrower, so a per-device verdict could commit a "
                    "loan's emissions while its owner rolls back")
            if self.inject_straggler_every < 0:
                raise ValueError(
                    f"inject_straggler_every must be >= 0, got "
                    f"{self.inject_straggler_every}")
            if self.n_buckets < self.opt_window + 2:
                raise ValueError(
                    f"opt_window={self.opt_window} needs n_buckets >= "
                    f"{self.opt_window + 2} (got {self.n_buckets}) — the "
                    "shadow window plus the live epoch must fit the bucket "
                    "ring without wrapping onto itself")
            if self.opt_stage_cap == 0:
                object.__setattr__(self, "opt_stage_cap", self.route_cap)
            if self.opt_stage_cap < 1:
                raise ValueError(
                    f"opt_stage_cap must be >= 1 when speculating, got "
                    f"{self.opt_stage_cap}")
        else:
            # dead speculation knobs with W == 0 are rejected, not ignored:
            # a config that *looks* speculative but isn't would silently
            # change nothing.
            if self.opt_stage_cap:
                raise ValueError(
                    f"opt_stage_cap={self.opt_stage_cap} only applies with "
                    f"opt_window > 0 — it would silently do nothing")
            if self.opt_commit != "device":
                raise ValueError(
                    f"opt_commit={self.opt_commit!r} only applies with "
                    f"opt_window > 0 — it would silently do nothing")
            if self.opt_adaptive:
                raise ValueError(
                    "opt_adaptive=True only applies with opt_window > 0 — "
                    "the controller needs a window cap to tune under")
            if self.inject_straggler_every:
                raise ValueError(
                    f"inject_straggler_every={self.inject_straggler_every} "
                    "only applies with opt_window > 0 — there is no window "
                    "to abort")

        # stage-name validation against the registries (populated on package
        # import; imported lazily here so config stays cycle-free).
        from . import routers, schedulers  # noqa: F401  (registration import)
        from .base import BATCH_IMPLS, ROUTERS, SCHEDULERS
        if self.batch_impl not in BATCH_IMPLS:
            raise ValueError(f"unknown batch_impl {self.batch_impl!r} "
                             f"(choose from {sorted(BATCH_IMPLS)})")
        if self.route not in ROUTERS:
            raise ValueError(f"unknown route {self.route!r} "
                             f"(choose from {sorted(ROUTERS)})")
        internal = set(BATCH_IMPLS.values()) - {"batch"}
        known = sorted(set(SCHEDULERS) - internal | {"batch"})
        if self.scheduler in internal:
            # internal registry names — selecting one directly would let
            # scheduler and batch_impl disagree about what executes.
            raise ValueError(
                f"scheduler {self.scheduler!r} is internal; use "
                f"scheduler='batch' with batch_impl="
                f"{self.scheduler.split('-', 1)[1]!r}")
        if self.scheduler != "batch" and self.scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {self.scheduler!r} "
                             f"(choose from {known})")
        if self.batch_impl != "rounds" and self.scheduler != "batch":
            raise ValueError(
                f"batch_impl={self.batch_impl!r} requires scheduler='batch' "
                f"— with scheduler={self.scheduler!r} it would silently "
                "never take effect")
        if self.steal and (self.scheduler != "batch"
                           or self.batch_impl == "model"):
            # loaned batches are concatenated onto the local extract and run
            # through the rounds-family scheduler (dense or width-packed);
            # a model-specific whole-batch kernel can't ingest the augmented
            # arrays, and silently ignoring another scheduler would change
            # semantics with no Stats counter set.
            raise ValueError(
                f"steal=True only supports scheduler='batch' with "
                f"batch_impl in ('rounds', 'packed') (got "
                f"scheduler={self.scheduler!r}, "
                f"batch_impl={self.batch_impl!r})")

    def validate(self, n_devices: int) -> None:
        """Device-count-dependent fail-fast checks (engine construction)."""
        if self.route == "a2a":
            if self.route_cap < n_devices:
                raise ValueError(
                    f"route_cap={self.route_cap} must be >= n_devices="
                    f"{n_devices} for a2a routing — the per-pair sub-buffer "
                    "(route_cap // n_devices) would be empty and every event "
                    "would spill to fallback instead of being exchanged")
            if self.route_cap % n_devices:
                raise ValueError(
                    f"route_cap={self.route_cap} must be divisible by mesh "
                    f"size {n_devices} for a2a")
