"""Stage interfaces and registries for the engine pipeline.

The PARSIR epoch step is architecturally a fixed pipeline

    extract → steal → process → rebalance → route → deliver

and this module defines the narrow interfaces of its pluggable stages:

  * :class:`Scheduler` — how a device's per-epoch event batch is executed
    (PARSIR batch rounds, width-packed tiles, lowest-timestamp-first, or a
    model-provided whole-batch kernel);
  * :class:`Router` — how emitted events reach their owners (`allgather`
    broadcast or pairwise `a2a` exchange);
  * :class:`StealPolicy` — whether/how epoch-granular object loans rebalance
    load before processing;
  * :class:`RebalancePolicy` — whether/how the placement boundaries move at
    epoch boundaries (object + calendar-row migration).

Implementations are small registered classes (``@register_scheduler("ltf")``
…); :class:`~repro.core.pipeline.config.EngineConfig` selects them by name and
:func:`repro.core.pipeline.step.make_step` only wires them together.  Shared
engine types (``Stats``, ``EngineState``, epoch arithmetic) live here too so
every stage module can import them without cycles.

Bit-exactness contract: a stage implementation chooses *how* — an execution
schedule, an exchange topology, a load split — never *what*.  Every
registered implementation of every stage must leave the simulation's
semantics untouched: the same processed-event multiset and (for dyadic
workloads) bit-identical object state as the sequential oracle, for every
composition of stages.  The differential conformance harness
(:mod:`repro.testing.conformance`) sweeps the registry cross-product to
enforce exactly this; register a new stage and the sweep inherits it.
"""
from __future__ import annotations

import abc
import math
from typing import TYPE_CHECKING, Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..api import SimModel
from ..calendar import Calendar, Fallback
from ..events import EventBatch
from ..placement import Placement
from .names import BATCH_IMPLS  # noqa: F401  (re-export; names.py is jax-free)

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from .config import EngineConfig

#: mesh axis name of the worker dimension (one program instance per device).
AXIS = "workers"


class Stats(NamedTuple):
    processed: jax.Array             # events processed on this device
    cal_overflow: jax.Array          # bucket-capacity overflows (must be 0)
    fb_overflow: jax.Array           # fallback-capacity overflows (must be 0)
    route_overflow: jax.Array        # route-capacity overflows (must be 0)
    late_events: jax.Array           # causality violations (must be 0)
    lookahead_violations: jax.Array  # model emitted ts < ts_in + L (must be 0)
    stolen: jax.Array                # loaned batches processed on this device
    oob_events: jax.Array            # emitted dst outside [0, n_objects) (must be 0)
    rebalances: jax.Array            # adaptive-placement rebalance firings
    migrated: jax.Array              # object rows received via rebalance migration
    rollbacks: jax.Array             # speculation windows aborted (straggler hit)
    speculated: jax.Array            # events processed past the safe horizon
    #                                  and committed (never counts aborted work)
    spec_commits: jax.Array          # speculation windows committed


def stats_dtype() -> jnp.dtype:
    """Counter dtype for the in-carry Stats ledger.

    int64 when the runtime allows it (``JAX_ENABLE_X64=1``) — wide enough for
    any campaign; int32 otherwise (the JAX default truncates int64 silently),
    in which case the engine *fails fast* before any dispatch whose
    worst-case per-counter increment could overflow
    (:meth:`repro.core.engine.ParsirEngine` checks the bound).
    """
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def zero_stats() -> Stats:
    z = jnp.zeros((1,), stats_dtype())
    return Stats(*(z,) * len(Stats._fields))


class EngineState(NamedTuple):
    cal: Calendar
    fb: Fallback
    obj: Any
    epoch: jax.Array   # i32 [1] per device (identical everywhere)
    stats: Stats
    bounds: jax.Array  # i32 [1, n_devices + 1] per device (identical everywhere)
    load: jax.Array    # i32 [n_local_max] per-object processed counts since
    #                    the last rebalance (measured placement weights)


def epoch_of(ts: jax.Array, epoch_len: float) -> jax.Array:
    return jnp.floor(ts * jnp.float32(1.0 / epoch_len)
                     if math.log2(1.0 / epoch_len).is_integer()
                     else ts / jnp.float32(epoch_len)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# stage interfaces
# ---------------------------------------------------------------------------

#: a scheduler's result: (updated object pytree, flat emitted EventBatch,
#: lookahead-violation count).
ProcessResult = tuple[Any, EventBatch, jax.Array]


class Scheduler(abc.ABC):
    """Per-epoch batch execution strategy (pipeline stage 3, paper §II-A).

    Contract: a scheduler is a *schedule*, never a semantics change.  It
    must process each object's epoch batch in timestamp order (intra-object
    causality) and call the model's ``process_event`` with exactly the
    extracted (ts, seed, payload) values — so any scheduler, at any tile
    width or round order, produces bit-identical object state and the
    identical emitted-event multiset.
    """

    name: str

    def validate(self, model: SimModel, cfg: "EngineConfig") -> None:
        """Fail fast at engine construction if the model/config can't run."""

    @abc.abstractmethod
    def process(self, model: SimModel, cfg: "EngineConfig", obj: Any,
                ts_s: jax.Array, seed_s: jax.Array, pay_s: jax.Array,
                cnt_b: jax.Array) -> ProcessResult:
        """Apply every object's sorted epoch batch; return emitted events.

        Inputs are the per-object [n_local, cap] arrays of
        :func:`repro.core.calendar.extract_sorted`; ``cfg`` carries the
        execution knobs a scheduler may consult (``lookahead``,
        ``pack_tile``, …).  The returned EventBatch is flat with ``valid``
        masks honored downstream — a scheduler may emit 0..``model.max_out``
        events per processed event.
        """


class Router(abc.ABC):
    """Event exchange strategy (pipeline stage 5, paper §II-B).

    Contract: routing moves events, never invents, drops or reorders them.
    Events that don't fit the route buffer must be handed back (the caller
    parks them in the fallback list) and any true capacity loss *counted* —
    the conformance harness asserts the counters stay zero and the pending
    multiset matches the oracle under either topology.

    ``replicated`` declares the exchange's output topology so per-event
    counters downstream can be reduced correctly: True means every device
    sees the *same* routed batch (allgather broadcast — count each event
    once globally, e.g. on device 0), False means each device sees a
    *distinct* slice (pairwise a2a — every device counts its own events).
    Getting this wrong silently over- or under-counts delivery-side
    ``oob_events``.
    """

    name: str
    #: True if exchange() presents an identical batch on every device
    #: (broadcast); False if each device receives a distinct slice.
    replicated: bool = True

    def validate(self, cfg: "EngineConfig", placement: Placement) -> None:
        """Fail fast at engine construction on bad capacity/topology."""

    @abc.abstractmethod
    def select_send(self, prod: EventBatch, eligible: jax.Array,
                    placement: Placement, cfg: "EngineConfig"
                    ) -> tuple[EventBatch, jax.Array, jax.Array]:
        """Pick which eligible produced events ride this epoch's exchange.

        Returns (route buffer, sent-mask over ``prod``, overflow count).
        Unsent valid events are the caller's to park in the fallback buffer.
        """

    @abc.abstractmethod
    def exchange(self, buf: EventBatch, placement: Placement,
                 cfg: "EngineConfig") -> EventBatch:
        """Run the collective; return the events visible to this device."""

    def sender_ids(self, placement: Placement, cfg: "EngineConfig"
                   ) -> jax.Array:
        """Source device of each slot in an :meth:`exchange` output batch.

        A static i32 vector matching the exchange output's slot count —
        both built-in exchanges pack by source positionally, so provenance
        is recoverable without widening the event record.  The speculation
        stage uses it to filter speculative arrivals by the *sender's*
        commit verdict (``opt_commit='device'``); a custom router must
        override this to compose with per-device commit.
        """
        raise NotImplementedError(
            f"router {self.name!r} does not expose sender identity; "
            "override sender_ids() to compose with opt_commit='device'")


class StealPolicy(abc.ABC):
    """Load-balancing strategy (pipeline stage 2, paper §II-A)."""

    name: str

    @abc.abstractmethod
    def process(self, model: SimModel, scheduler: Scheduler,
                cfg: "EngineConfig", placement: Placement, dev: jax.Array,
                obj: Any, ts_s: jax.Array, seed_s: jax.Array,
                pay_s: jax.Array, cnt_b: jax.Array
                ) -> tuple[Any, EventBatch, jax.Array, jax.Array, jax.Array]:
        """Run stage 2+3 (rebalance, then process).

        Returns (obj, flat emitted EventBatch, lookahead violations,
        stolen-batch count, processed-event count).
        """


class RebalancePolicy(abc.ABC):
    """Placement-rebalancing strategy (epoch-boundary stage, paper §II-C).

    Where :class:`StealPolicy` loans an object's *current-epoch batch* and
    returns it (ownership never moves), a rebalance policy moves *ownership*:
    it recomputes the contiguous placement boundaries from measured load and
    migrates object state + calendar rows to the new owners.  It runs between
    the process and route stages, so the epoch's freshly emitted events are
    routed against the NEW boundaries, and fallback entries (which carry
    global dst) re-route themselves through the existing routers on the next
    epochs — no fallback migration is needed.
    """

    name: str

    @abc.abstractmethod
    def rebalance(self, cfg: "EngineConfig", placement: Placement,
                  dev: jax.Array, cur: jax.Array, bounds: jax.Array,
                  load: jax.Array, cal: Calendar, obj: Any
                  ) -> tuple[jax.Array, jax.Array, Calendar, Any,
                             jax.Array, jax.Array]:
        """Maybe move the boundaries and migrate rows.

        ``bounds`` is the live i32[n_devices+1] boundaries vector, ``load``
        the per-local-row processed counts accumulated since the last firing
        (this epoch included).  Returns (bounds, load, cal, obj,
        n_rows_received, fired ∈ {0, 1}); non-firing epochs return everything
        unchanged.
        """


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

SCHEDULERS: dict[str, Scheduler] = {}
ROUTERS: dict[str, Router] = {}
STEAL_POLICIES: dict[str, StealPolicy] = {}
REBALANCERS: dict[str, RebalancePolicy] = {}


def _register(registry: dict, kind: str, name: str) -> Callable:
    def deco(cls):
        if name in registry:
            raise ValueError(f"{kind} {name!r} already registered")
        cls.name = name
        registry[name] = cls()
        return cls
    return deco


def register_scheduler(name: str):
    """Class decorator: register a :class:`Scheduler` under ``name``."""
    return _register(SCHEDULERS, "scheduler", name)


def register_router(name: str):
    """Class decorator: register a :class:`Router` under ``name``."""
    return _register(ROUTERS, "router", name)


def register_steal_policy(name: str):
    """Class decorator: register a :class:`StealPolicy` under ``name``."""
    return _register(STEAL_POLICIES, "steal policy", name)


def register_rebalancer(name: str):
    """Class decorator: register a :class:`RebalancePolicy` under ``name``."""
    return _register(REBALANCERS, "rebalancer", name)


def resolve_scheduler(cfg: "EngineConfig") -> Scheduler:
    """EngineConfig → Scheduler.

    The PARSIR ``batch`` scheduler is further split by ``batch_impl``
    (``rounds`` = vmap loop, ``packed`` = width-packed tiles, ``model`` = the
    model's whole-batch kernel), preserving the historical config surface;
    any other name (``ltf``, or a user-registered scheduler) is looked up
    directly.
    """
    if cfg.scheduler == "batch":
        return SCHEDULERS[BATCH_IMPLS[cfg.batch_impl]]
    return SCHEDULERS[cfg.scheduler]


def resolve_router(name: str) -> Router:
    return ROUTERS[name]


def resolve_steal(cfg: "EngineConfig", n_devices: int) -> StealPolicy:
    if cfg.steal and n_devices > 1:
        return STEAL_POLICIES["loan"]
    return STEAL_POLICIES["none"]


def resolve_rebalance(cfg: "EngineConfig") -> RebalancePolicy:
    if cfg.placement == "adaptive":
        return REBALANCERS["adaptive"]
    return REBALANCERS["none"]
