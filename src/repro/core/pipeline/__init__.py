"""The engine as a pipeline of composable stages.

PARSIR's epoch step is a fixed pipeline — extract, steal, batch-process,
route, deliver — and this package gives each stage a narrow interface and a
registry, so new schedulers / routers / steal policies are small registered
classes instead of string-dispatched branches inside one monolithic module:

  * :mod:`base`        — stage interfaces, registries, shared engine types;
  * :mod:`config`      — :class:`EngineConfig` (stage selection + capacities,
    fail-fast validation);
  * :mod:`schedulers`  — ``batch`` (PARSIR rounds), ``batch-packed``
    (width-packed tiles), ``batch-model`` (model kernel), ``ltf``;
  * :mod:`packing`     — the width-packer: pack/unpack between the padded
    calendar slice and the dense round-major work list;
  * :mod:`routers`     — ``allgather``, ``a2a``;
  * :mod:`steal`       — ``none``, ``loan``;
  * :mod:`rebalance`   — ``none``, ``adaptive`` (epoch-boundary placement
    rebalancing with object migration);
  * :mod:`deliver`     — owner-side calendar/fallback insertion;
  * :mod:`step`        — :func:`make_step`, the wiring;
  * :mod:`speculate`   — :func:`make_spec_step`, the bounded-optimism
    (Time Warp lite) step used when ``EngineConfig.opt_window > 0``.

Registering a new stage::

    from repro.core.pipeline import Scheduler, register_scheduler

    @register_scheduler("my-sched")
    class MyScheduler(Scheduler):
        def process(self, model, cfg, obj, ts_s, seed_s, pay_s, cnt_b):
            ...

    EngineConfig(lookahead=0.5, scheduler="my-sched")
"""
from . import rebalance, routers, schedulers, steal  # noqa: F401  (registration imports)
from .packing import PackedSlice, pack_slice, unpack_slice
from .base import (AXIS, REBALANCERS, ROUTERS, SCHEDULERS, STEAL_POLICIES,
                   EngineState, RebalancePolicy, Router, Scheduler, Stats,
                   StealPolicy, epoch_of, register_rebalancer,
                   register_router, register_scheduler, register_steal_policy,
                   resolve_rebalance, resolve_router, resolve_scheduler,
                   resolve_steal, zero_stats)
from .config import EngineConfig
from .deliver import deliver
from .speculate import make_spec_step
from .step import make_step

__all__ = [
    "AXIS", "REBALANCERS", "ROUTERS", "SCHEDULERS", "STEAL_POLICIES",
    "EngineConfig", "EngineState", "Stats",
    "RebalancePolicy", "Router", "Scheduler", "StealPolicy",
    "register_rebalancer", "register_router", "register_scheduler",
    "register_steal_policy",
    "resolve_rebalance", "resolve_router", "resolve_scheduler",
    "resolve_steal",
    "epoch_of", "zero_stats", "deliver", "make_step", "make_spec_step",
    "PackedSlice", "pack_slice", "unpack_slice",
]
