"""Bounded-optimism speculation: the Time Warp-lite epoch step (opt_window).

With ``EngineConfig.opt_window = W > 0`` one step commits the *safe* epoch
``e0`` conservatively and then speculates up to ``W`` further epochs against
a shadow copy of the touched state — the per-object state pytree plus the
``W`` calendar buckets of the window (O(W) rows per object, via
:func:`repro.core.calendar.take_buckets` / ``put_buckets``, the epoch-axis
complement of the PR 3 row-migration machinery).  Straggler detection
happens at route/deliver time: any arriving event whose epoch falls inside
the already-speculated window is a violation at the *receiving* device.

**Commit locality** (``opt_commit``).  With ``"global"`` the window is
globally atomic: one replicated verdict, every device commits or rolls back
together (PR 9 semantics, bit-for-bit).  With ``"device"`` (the default)
each device decides alone — a device keeps its speculated window iff

  * it received no straggler itself (``v_local == 0``), **and**
  * its window does not outrun the earliest straggler *anywhere*
    (``e0 + W_eff <= m_global``, the horizon guard).

The horizon guard is what makes local verdicts sound.  A violated device
restores its shadow and re-executes ``e0+1 ..`` conservatively; its
re-execution can diverge from round 1 only at epochs ``>= m_global`` (below
that, the restored state and the absence of sub-``m_global`` arrivals make
re-processing bit-identical — counter-based RNG), so divergent emissions
land at epochs ``>= m_global + 1 >`` every keeper's committed horizon.  The
bit-identical re-emissions below that are *re-sent* — so keepers filter the
speculative exchange by sender: an aborting sender's round-1 speculative
arrivals are dropped everywhere (``keep_vec[sender_ids]``) and arrive
exactly once via the re-execution.  Conversely a keeper's committed
speculative emissions are delivered even on the abort branch (the keeper
never re-sends them); they carry epochs beyond the restored window's drain
point, so the violated receiver simply re-processes them with the straggler
included.  Staging/route overflow on the speculative path contributes a
violation *at the sender* with horizon ``e0 + 1`` — the sender re-emits
conservatively and no keeper can have outrun the lost event (speculative
emissions carry epochs ``>= e0 + 2``).

Mixed verdicts advance the *replicated* epoch by 1 (a keeper re-walks its
committed epochs as empty-bucket no-ops) and keepers deliver at ``cur =
e0`` — their arrivals all carry epochs past the window (``v_local == 0``),
so nothing is late and anything beyond the ring horizon parks in the
fallback.  Only a unanimous commit leaps the epoch by ``W_eff + 1``.

Why the whole window per device, not per-object rollback: objects consume
each other's *speculative* emissions inside the window (that is the point —
intra-window event chains are what a pure leap would stall on), and
calendar slots carry no provenance, so invalidating one object would
require tracing a cascade the dataflow no longer records.  Aborting a
device's window wholesale needs no anti-messages: speculative emissions are
either parked in a staging buffer (remote dst, or local beyond the window)
or inserted into shadowed buckets, so discarding staging + restoring the
shadow erases every local speculative effect exactly.

**Compositions.**  ``steal=True`` runs the loan policy in the safe epoch
*and* the sub-epochs (the loan collectives sit under a replicated-predicate
``lax.cond``, the same discipline as the adaptive rebalancer) but requires
``opt_commit='global'``: a loaned batch executes on the borrower, so a
split verdict could commit the borrower's staged loan emissions while the
aborting owner re-executes the loaned batch — duplicates.  Globally atomic
commit keeps loan effects and their rollback in lockstep.
``placement='adaptive'`` composes with either commit mode: the rebalance
stage fires only in the (always-committed) safe section, and ``W_eff`` is
clamped so no speculative epoch lands on or leaps a firing epoch — every
firing executes as a safe epoch, exactly as the conservative engine would.

**Determinism harness.**  ``inject_straggler_every = n`` forces every
``n``-th window (counted per device; the count is replicated in value) to
abort by synthesizing a violation at ``e0 + 1`` on every device — the
rollback/restore branch becomes deterministically reachable at D=1 in
tier-1 tests.  The injection is schedule-only (abort is the conservative
path) and touches only the ``rollbacks`` activity meter.

The step body, in order (collectives never inside the commit/abort
branches; the loan/rebalance collectives run under replicated predicates):

  1. **safe sub-epoch** ``e0`` — extract, steal + process via the
     configured policy, rebalance (adaptive placement; fresh emissions are
     routed against the new boundaries), route/deliver.  All of this is
     committed regardless of the window's fate.
  2. **shadow** — snapshot object state + window buckets ``e0+1 .. e0+W``
     (post-rebalance, so a restore never undoes a migration).
  3. **speculative sub-epochs** ``e0+w``, ``w = 1 .. W_eff`` — extract,
     steal + process; emissions with local dst inside the shadowed window
     deliver immediately (feeding later sub-epochs); everything else
     (remote, or local beyond the window) parks in the staging buffer.
  4. **two exchanges** — the safe buffer (must-keep: delivered in both
     branches) and the staged remote in-horizon events (sender-filtered by
     the verdict).  Two collectives instead of one is what makes abort
     possible without anti-messages.
  5. **verdict** — one ``all_gather`` of ``[m_local, v_local]`` (earliest
     in-window arrival epoch, violation count) replicates every device's
     verdict inputs; ``keep_d`` / ``keep_vec`` derive locally.
  6. **per-device commit or abort** — ``lax.cond(keep_d, commit, abort)``
     with local ops only.  Progress is guaranteed: the safe epoch commits
     either way, so a workload with constant cross-device traffic degrades
     to conservative speed — never to livelock, and never to wrong bits.

``rollbacks`` / ``speculated`` / ``spec_commits`` are activity meters, not
error counters — deliberately absent from ``CLEAN_COUNTERS``.  Every
device increments exactly one of ``spec_commits`` / ``rollbacks`` per
window, so per device (and divided by D across devices) their sum equals
the fused-loop iteration count.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..api import SimModel
from ..calendar import (Fallback, extract_sorted, fallback_put, insert,
                        put_buckets, take_buckets)
from ..events import (EventBatch, compact, compact_mask, concat_batches,
                      empty_batch, truncate)
from ..placement import Placement
from . import rebalance, routers, schedulers, steal  # noqa: F401  (registration imports)
from .base import (AXIS, EngineState, epoch_of, resolve_rebalance,
                   resolve_router, resolve_scheduler, resolve_steal)
from .config import EngineConfig
from .deliver import deliver

#: "no in-window arrival" marker for the per-device earliest-straggler epoch.
NO_STRAGGLER = jnp.iinfo(jnp.int32).max


def _stage_put(staging: EventBatch, new: EventBatch):
    """Append valid events of ``new`` into the staging buffer (compacting).

    Same discipline as :func:`repro.core.calendar.fallback_put`, on a bare
    EventBatch: overflow is *counted* — the step turns it into an abort, so
    a too-small ``opt_stage_cap`` costs speed, never events.
    """
    merged = compact(concat_batches(staging, new))
    cap = staging.capacity
    spill = jnp.sum(merged.valid[..., cap:].astype(jnp.int32))
    return truncate(merged, cap), spill


def make_spec_step(model: SimModel, cfg: EngineConfig, placement: Placement
                   ) -> Callable[[EngineState, jax.Array], EngineState]:
    """Build the speculative step closure: ``step(state, bound)``.

    ``bound`` is the exclusive epoch bound of the enclosing run/drain loop
    (a traced operand): the window is clamped to ``W_eff = min(W, bound - 1
    - e0)`` so a speculative step never processes an epoch the caller did
    not ask for — ``run(n)`` lands on exactly epoch ``n``, and conformance
    against the oracle's fixed horizon stays exact.
    """
    D = placement.n_devices
    N = cfg.n_buckets
    O = placement.n_objects
    W = cfg.opt_window
    assert W > 0, "make_spec_step requires opt_window > 0 (use make_step)"

    scheduler = resolve_scheduler(cfg)
    router = resolve_router(cfg.route)
    policy = resolve_steal(cfg, D)
    rebalancer = resolve_rebalance(cfg)
    adaptive = cfg.placement == "adaptive"
    per_device = cfg.opt_commit == "device"
    inject = cfg.inject_straggler_every
    scheduler.validate(model, cfg)
    router.validate(cfg, placement)
    senders = router.sender_ids(placement, cfg)

    def step(state: EngineState, bound: jax.Array) -> EngineState:
        dev = jax.lax.axis_index(AXIS)
        e0 = state.epoch[0]
        pl = placement.with_boundaries(state.bounds[0])
        w_eff = jnp.clip(bound - 1 - e0, 0, W)
        if adaptive:
            # never speculate onto (or leap over) a rebalance firing epoch:
            # firings run only in the safe section, so the window must stop
            # short of the next epoch with (e + 1) % R == 0.
            R = cfg.rebalance_every
            d_fire = (R - 1 - (e0 % R)) % R
            w_eff = jnp.minimum(
                w_eff, jnp.where(d_fire == 0, R - 1, d_fire - 1))

        # -- 1. safe sub-epoch e0 (committed in both branches) --------------
        cal, ts_s, seed_s, pay_s, cnt_b = extract_sorted(state.cal, e0)
        obj, out_flat, lv0, stolen0, proc0 = policy.process(
            model, scheduler, cfg, pl, dev, state.obj,
            ts_s, seed_s, pay_s, cnt_b)

        if adaptive:
            load = state.load + cnt_b
            bounds, load, cal, obj, migrated, fired = rebalancer.rebalance(
                cfg, placement, dev, e0, state.bounds[0], load, cal, obj)
            pl = placement.with_boundaries(bounds)
        else:
            bounds, load = state.bounds[0], state.load
            migrated = fired = jnp.int32(0)
        boundaries = jnp.asarray(pl.boundaries, jnp.int32)

        prod = concat_batches(out_flat, state.fb.events)
        ep_p = epoch_of(prod.ts, cfg.epoch_len)
        oob_p = prod.valid & ((prod.dst < 0) | (prod.dst >= O))
        n_oob0 = jnp.sum(oob_p.astype(jnp.int32))
        late_p = prod.valid & ~oob_p & (ep_p <= e0)
        n_late0 = jnp.sum(late_p.astype(jnp.int32))
        good = prod.valid & ~oob_p & ~late_p
        local = good & (pl.owner(prod.dst) == dev)

        # remote in-horizon events ride the (must-keep) safe exchange; local
        # events skip the collective and deliver immediately — the window's
        # sub-epochs must see them, and slot order inside a bucket is
        # irrelevant (extraction re-sorts by (ts, seed)).
        remote_eligible = good & ~local & (ep_p <= e0 + N)
        safe_buf, send, route_ovf0 = router.select_send(prod, remote_eligible,
                                                        pl, cfg)
        kept = compact_mask(prod, good & ~local & ~send)
        fb = Fallback(truncate(kept, cfg.fallback_cap))
        fb_ovf0 = jnp.sum(kept.valid[cfg.fallback_cap:].astype(jnp.int32))
        cal, fb, cal_ovf0, fb_ovf0b, late0b, _ = deliver(
            cal, fb, prod._replace(valid=local), e0, dev, pl, cfg,
            init=False, replicated=False)

        # -- 2. shadow: window buckets + object state ------------------------
        shadow_cal = take_buckets(cal, e0 + 1, W)
        shadow_obj = obj

        # -- 3. speculative sub-epochs --------------------------------------
        zero = jnp.int32(0)
        staging = empty_batch(cfg.opt_stage_cap)
        # (cal, obj, staging, processed, lookahead, late, oob, cal_ovf,
        #  stage_ovf, stolen, load) — stage_ovf feeds the violation count,
        # the rest are Stats/load deltas applied only on commit.
        carry = (cal, obj, staging, zero, zero, zero, zero, zero, zero,
                 zero, jnp.zeros_like(load))

        def sub_epoch(w):
            def run(c):
                (cal, obj, staging, proc, lv, late, oob, covf, sovf,
                 stl, ld) = c
                cur = e0 + w
                cal, ts_w, seed_w, pay_w, cnt_w = extract_sorted(cal, cur)
                obj, out_w, lv_w, stl_w, proc_w = policy.process(
                    model, scheduler, cfg, pl, dev, obj,
                    ts_w, seed_w, pay_w, cnt_w)
                ep_w = epoch_of(out_w.ts, cfg.epoch_len)
                oob_w = out_w.valid & ((out_w.dst < 0) | (out_w.dst >= O))
                late_w = out_w.valid & ~oob_w & (ep_w <= cur)
                good_w = out_w.valid & ~oob_w & ~late_w
                # local + inside the shadowed window → insert now (later
                # sub-epochs consume it); anything else parks in staging.
                ins = good_w & (pl.owner(out_w.dst) == dev) & (ep_w <= e0 + W)
                lidx = jnp.clip(out_w.dst - boundaries[dev], 0,
                                cal.n_local - 1)
                cal, covf_w = insert(cal, lidx, ep_w, out_w.ts, out_w.seed,
                                     out_w.payload, ins)
                staging, sovf_w = _stage_put(
                    staging, compact_mask(out_w, good_w & ~ins))
                return (cal, obj, staging, proc + proc_w, lv + lv_w,
                        late + jnp.sum(late_w.astype(jnp.int32)),
                        oob + jnp.sum(oob_w.astype(jnp.int32)),
                        covf + covf_w, sovf + sovf_w, stl + stl_w,
                        ld + cnt_w)
            return run

        for w in range(1, W + 1):
            carry = jax.lax.cond(w <= w_eff, sub_epoch(w), lambda c: c, carry)
        (cal_sp, obj_sp, staging, spec_proc, spec_lv, spec_late, spec_oob,
         spec_covf, stage_ovf, spec_stolen, load_sp) = carry

        # -- 4. the two exchanges (unconditional: collectives stay out of
        #       the commit/abort branches) ---------------------------------
        routed_safe = router.exchange(safe_buf, pl, cfg)

        ep_st = epoch_of(staging.ts, cfg.epoch_len)
        stage_remote = staging.valid & (pl.owner(staging.dst) != dev)
        # remote staged events up to the post-commit horizon ride the spec
        # exchange — including window-epoch stragglers, whose *arrival* is
        # exactly what the owner's violation count detects.
        spec_eligible = stage_remote & (ep_st <= e0 + w_eff + N)
        spec_buf, spec_send, spec_route_ovf = router.select_send(
            staging, spec_eligible, pl, cfg)
        routed_spec = router.exchange(spec_buf, pl, cfg)

        # -- 5. verdict: (earliest straggler epoch, violation count) --------
        def violations(batch: EventBatch):
            ep = epoch_of(batch.ts, cfg.epoch_len)
            mine = (batch.valid & (batch.dst >= 0) & (batch.dst < O)
                    & (pl.owner(batch.dst) == dev))
            viol = mine & (ep <= e0 + w_eff)
            return (jnp.sum(viol.astype(jnp.int32)),
                    jnp.min(jnp.where(viol, ep, NO_STRAGGLER)))

        # a staged/spec-routed event the buffers couldn't carry must abort
        # *its sender*: parking it for a later epoch could make it LATE
        # (dropped), and a conservative engine never drops — the abort
        # re-emits it.  Its horizon contribution is e0+1 (conservative: the
        # lost events themselves carry epochs >= e0+2).
        cnt_sf, m_sf = violations(routed_safe)
        cnt_sp, m_sp = violations(routed_spec)
        v_local = cnt_sf + cnt_sp + stage_ovf + spec_route_ovf
        m_local = jnp.minimum(m_sf, m_sp)
        m_local = jnp.where(stage_ovf + spec_route_ovf > 0,
                            jnp.minimum(m_local, e0 + 1), m_local)

        if inject > 0:
            # deterministic straggler injection: every inject-th window is
            # forced down the abort path on every device (the count below is
            # replicated in value — each device resolves one verdict per
            # window).  Schedule-only: abort IS the conservative path.
            windows = state.stats.spec_commits[0] + state.stats.rollbacks[0]
            fire_inj = (windows % inject == inject - 1) & (w_eff > 0)
            v_local = v_local + jnp.where(fire_inj, 1, 0).astype(jnp.int32)
            m_local = jnp.where(fire_inj, jnp.minimum(m_local, e0 + 1),
                                m_local)

        g = jax.lax.all_gather(jnp.stack([m_local, v_local]), AXIS)  # [D, 2]
        m_global = jnp.min(g[:, 0])
        all_commit = m_global == NO_STRAGGLER
        if per_device:
            keep_vec = (g[:, 1] == 0) & (e0 + w_eff <= m_global)
            keep_d = (v_local == 0) & (e0 + w_eff <= m_global)
        else:
            keep_vec = jnp.broadcast_to(all_commit, (g.shape[0],))
            keep_d = all_commit

        # replicated across devices even when verdicts differ: a mixed
        # verdict advances by 1 (keepers re-walk committed epochs as empty
        # no-ops) and keepers deliver at cur = e0 — their arrivals are all
        # past the window (v_local == 0), so nothing lands late and
        # beyond-horizon arrivals park in the fallback.
        e_next = jnp.where(all_commit, e0 + w_eff + 1, e0 + 1)
        cur_c = jnp.where(all_commit, e0 + w_eff, e0)

        # speculative arrivals filtered by the *sender's* verdict: an
        # aborting sender re-executes and re-sends (drop round 1 here); a
        # keeper never re-sends (deliver round 1, even into an abort).
        spec_arrivals = routed_spec._replace(
            valid=routed_spec.valid & keep_vec[senders])

        # -- 6. commit or roll back (local ops only) ------------------------
        def commit(_):
            c, f, co1, fo1, l1, _ = deliver(
                cal_sp, fb, routed_safe, cur_c, dev, pl, cfg, init=False,
                replicated=router.replicated)
            c, f, co2, fo2, l2, _ = deliver(
                c, f, spec_arrivals, cur_c, dev, pl, cfg, init=False,
                replicated=router.replicated)
            # staged leftovers: local beyond the window → deliver (insert or
            # park); remote beyond the post-commit horizon → fallback, to
            # re-offer through routing on later epochs.
            leftover = staging.valid & ~spec_send
            lo_local = leftover & (pl.owner(staging.dst) == dev)
            c, f, co3, fo3, l3, _ = deliver(
                c, f, staging._replace(valid=lo_local), cur_c, dev, pl, cfg,
                init=False, replicated=False)
            f, fo4 = fallback_put(
                f, staging._replace(valid=leftover & ~lo_local))
            deltas = (spec_proc, spec_lv, spec_late, spec_oob,
                      spec_covf + co1 + co2 + co3, fo1 + fo2 + fo3 + fo4,
                      l1 + l2 + l3, zero, jnp.int32(1), spec_proc,
                      spec_stolen, load_sp)
            return c, f, obj_sp, deltas

        def abort(_):
            c = put_buckets(cal_sp, e0 + 1, shadow_cal)
            c, f, co1, fo1, l1, _ = deliver(
                c, fb, routed_safe, cur_c, dev, pl, cfg, init=False,
                replicated=router.replicated)
            # keepers' committed speculative emissions still arrive (they
            # are never re-sent): epochs >= e0+2, into the restored ring.
            c, f, co2, fo2, l2, _ = deliver(
                c, f, spec_arrivals, cur_c, dev, pl, cfg, init=False,
                replicated=router.replicated)
            deltas = (zero, zero, zero, zero, co1 + co2, fo1 + fo2, l1 + l2,
                      jnp.int32(1), zero, zero, zero,
                      jnp.zeros_like(load_sp))
            return c, f, shadow_obj, deltas

        cal_f, fb_f, obj_f, deltas = jax.lax.cond(
            keep_d, commit, abort, None)
        (d_proc, d_lv, d_late, d_oob, d_covf, d_fovf, d_l2,
         d_rb, d_cm, d_spec, d_stolen, d_load) = deltas

        load_f = load + d_load if adaptive else load
        st = state.stats
        stats = st._replace(
            processed=st.processed + proc0 + d_proc,
            cal_overflow=st.cal_overflow + cal_ovf0 + d_covf,
            fb_overflow=st.fb_overflow + fb_ovf0 + fb_ovf0b + d_fovf,
            route_overflow=st.route_overflow + route_ovf0,
            late_events=st.late_events + n_late0 + late0b + d_late + d_l2,
            lookahead_violations=st.lookahead_violations + lv0 + d_lv,
            stolen=st.stolen + stolen0 + d_stolen,
            oob_events=st.oob_events + n_oob0 + d_oob,
            rebalances=st.rebalances + fired,
            migrated=st.migrated + migrated,
            rollbacks=st.rollbacks + d_rb,
            speculated=st.speculated + d_spec,
            spec_commits=st.spec_commits + d_cm,
        )
        return EngineState(cal_f, fb_f, obj_f,
                           jnp.reshape(e_next, state.epoch.shape), stats,
                           bounds[None, :], load_f)

    return step
