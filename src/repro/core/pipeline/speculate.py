"""Bounded-optimism speculation: the Time Warp-lite epoch step (opt_window).

With ``EngineConfig.opt_window = W > 0`` one step commits the *safe* epoch
``e0`` conservatively and then speculates up to ``W`` further epochs against
a shadow copy of the touched state — the per-object state pytree plus the
``W`` calendar buckets of the window (O(W) rows per object, via
:func:`repro.core.calendar.take_buckets` / ``put_buckets``, the epoch-axis
complement of the PR 3 row-migration machinery).  The window is **globally
atomic**: straggler detection happens at route/deliver time (any arriving
event whose epoch falls inside the already-speculated window, on any
device), the violation count is psum-reduced, and a nonzero count rolls
*every* device back to its shadow before the epochs are re-processed
conservatively on later steps.  Commit or abort, the drained state is
bit-exact with the conservative path — same golden digests; the conformance
sweep's ``speculation`` axis is the proof.

Why the whole window, not per-object rollback: objects consume each other's
*speculative* emissions inside the window (that is the point — intra-window
event chains are what a pure leap would stall on), and calendar slots carry
no provenance, so invalidating one object would require tracing a cascade
the dataflow no longer records.  Aborting the window wholesale needs no
anti-messages and no provenance: speculative emissions are either parked in
a staging buffer (remote dst, or local beyond the window) or inserted into
shadowed buckets, so discarding staging + restoring the shadow erases every
speculative effect exactly.

The step body, in order (collectives never inside a branch):

  1. **safe sub-epoch** ``e0`` — extract, process; local emissions (and
     local fallback re-offers) deliver immediately; remote in-horizon
     emissions enter the safe route buffer; the fallback is rebuilt.  All
     of this is committed regardless of the window's fate.
  2. **shadow** — snapshot object state + window buckets ``e0+1 .. e0+W``.
  3. **speculative sub-epochs** ``e0+w``, ``w = 1 .. W_eff`` (``W_eff``
     clamps the window to the run bound) — extract, process; emissions with
     local dst inside the shadowed window deliver immediately (feeding
     later sub-epochs); everything else (remote, or local beyond the
     window) parks in the staging buffer.  The fallback is never touched.
  4. **two exchanges** — the safe buffer (must-keep: delivered in both
     branches) and the staged remote in-horizon events (delivered on
     commit, discarded wholesale on abort).  Two collectives instead of one
     is what makes abort possible without anti-messages.
  5. **violation count** — arrivals (either exchange) whose epoch is
     ``<= e0 + W_eff``, plus staging/spec-route overflow (an event the
     speculative path couldn't carry must not be *delayed* into lateness —
     aborting re-emits it conservatively).  psum → identical verdict
     everywhere.
  6. **commit** (V == 0): keep speculated calendar/state, deliver both
     arrival sets and the staged leftovers at ``cur = e0 + W_eff``,
     advance the epoch by ``W_eff + 1``, fold the speculative Stats deltas
     in (``speculated += ``, ``spec_commits += 1``).
     **abort** (V > 0): restore the shadow, deliver only the safe arrivals
     at ``cur = e0``, advance by 1, discard every speculative delta
     (``rollbacks += 1``).  Progress is guaranteed: the safe epoch commits
     either way, so a workload with constant cross-device traffic degrades
     to conservative speed — never to livelock, and never to wrong bits.

``rollbacks`` / ``speculated`` / ``spec_commits`` are activity meters, not
error counters — deliberately absent from ``CLEAN_COUNTERS``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..api import SimModel
from ..calendar import (Fallback, extract_sorted, fallback_put, insert,
                        put_buckets, take_buckets)
from ..events import (EventBatch, compact, compact_mask, concat_batches,
                      empty_batch, truncate)
from ..placement import Placement
from . import routers, schedulers  # noqa: F401  (registration imports)
from .base import (AXIS, EngineState, epoch_of, resolve_router,
                   resolve_scheduler)
from .config import EngineConfig
from .deliver import deliver


def _stage_put(staging: EventBatch, new: EventBatch):
    """Append valid events of ``new`` into the staging buffer (compacting).

    Same discipline as :func:`repro.core.calendar.fallback_put`, on a bare
    EventBatch: overflow is *counted* — the step turns it into an abort, so
    a too-small ``opt_stage_cap`` costs speed, never events.
    """
    merged = compact(concat_batches(staging, new))
    cap = staging.capacity
    spill = jnp.sum(merged.valid[..., cap:].astype(jnp.int32))
    return truncate(merged, cap), spill


def make_spec_step(model: SimModel, cfg: EngineConfig, placement: Placement
                   ) -> Callable[[EngineState, jax.Array], EngineState]:
    """Build the speculative step closure: ``step(state, bound)``.

    ``bound`` is the exclusive epoch bound of the enclosing run/drain loop
    (a traced operand): the window is clamped to ``W_eff = min(W, bound - 1
    - e0)`` so a speculative step never processes an epoch the caller did
    not ask for — ``run(n)`` lands on exactly epoch ``n``, and conformance
    against the oracle's fixed horizon stays exact.
    """
    D = placement.n_devices
    N = cfg.n_buckets
    O = placement.n_objects
    W = cfg.opt_window
    assert W > 0, "make_spec_step requires opt_window > 0 (use make_step)"

    scheduler = resolve_scheduler(cfg)
    router = resolve_router(cfg.route)
    scheduler.validate(model, cfg)
    router.validate(cfg, placement)

    def step(state: EngineState, bound: jax.Array) -> EngineState:
        dev = jax.lax.axis_index(AXIS)
        e0 = state.epoch[0]
        pl = placement.with_boundaries(state.bounds[0])
        boundaries = jnp.asarray(pl.boundaries, jnp.int32)
        w_eff = jnp.clip(bound - 1 - e0, 0, W)

        # -- 1. safe sub-epoch e0 (committed in both branches) --------------
        cal, ts_s, seed_s, pay_s, cnt_b = extract_sorted(state.cal, e0)
        obj, out_flat, lv0 = scheduler.process(model, cfg, state.obj,
                                               ts_s, seed_s, pay_s, cnt_b)
        proc0 = jnp.sum(cnt_b)

        prod = concat_batches(out_flat, state.fb.events)
        ep_p = epoch_of(prod.ts, cfg.epoch_len)
        oob_p = prod.valid & ((prod.dst < 0) | (prod.dst >= O))
        n_oob0 = jnp.sum(oob_p.astype(jnp.int32))
        late_p = prod.valid & ~oob_p & (ep_p <= e0)
        n_late0 = jnp.sum(late_p.astype(jnp.int32))
        good = prod.valid & ~oob_p & ~late_p
        local = good & (pl.owner(prod.dst) == dev)

        # remote in-horizon events ride the (must-keep) safe exchange; local
        # events skip the collective and deliver immediately — the window's
        # sub-epochs must see them, and slot order inside a bucket is
        # irrelevant (extraction re-sorts by (ts, seed)).
        remote_eligible = good & ~local & (ep_p <= e0 + N)
        safe_buf, send, route_ovf0 = router.select_send(prod, remote_eligible,
                                                        pl, cfg)
        kept = compact_mask(prod, good & ~local & ~send)
        fb = Fallback(truncate(kept, cfg.fallback_cap))
        fb_ovf0 = jnp.sum(kept.valid[cfg.fallback_cap:].astype(jnp.int32))
        cal, fb, cal_ovf0, fb_ovf0b, late0b, _ = deliver(
            cal, fb, prod._replace(valid=local), e0, dev, pl, cfg,
            init=False, replicated=False)

        # -- 2. shadow: window buckets + object state ------------------------
        shadow_cal = take_buckets(cal, e0 + 1, W)
        shadow_obj = obj

        # -- 3. speculative sub-epochs --------------------------------------
        zero = jnp.int32(0)
        staging = empty_batch(cfg.opt_stage_cap)
        # (cal, obj, staging, processed, lookahead, late, oob, cal_ovf,
        #  stage_ovf) — stage_ovf feeds the violation count, the rest are
        # Stats deltas applied only on commit.
        carry = (cal, obj, staging, zero, zero, zero, zero, zero, zero)

        def sub_epoch(w):
            def run(c):
                cal, obj, staging, proc, lv, late, oob, covf, sovf = c
                cur = e0 + w
                cal, ts_w, seed_w, pay_w, cnt_w = extract_sorted(cal, cur)
                obj, out_w, lv_w = scheduler.process(model, cfg, obj,
                                                     ts_w, seed_w, pay_w,
                                                     cnt_w)
                ep_w = epoch_of(out_w.ts, cfg.epoch_len)
                oob_w = out_w.valid & ((out_w.dst < 0) | (out_w.dst >= O))
                late_w = out_w.valid & ~oob_w & (ep_w <= cur)
                good_w = out_w.valid & ~oob_w & ~late_w
                # local + inside the shadowed window → insert now (later
                # sub-epochs consume it); anything else parks in staging.
                ins = good_w & (pl.owner(out_w.dst) == dev) & (ep_w <= e0 + W)
                lidx = jnp.clip(out_w.dst - boundaries[dev], 0,
                                cal.n_local - 1)
                cal, covf_w = insert(cal, lidx, ep_w, out_w.ts, out_w.seed,
                                     out_w.payload, ins)
                staging, sovf_w = _stage_put(
                    staging, compact_mask(out_w, good_w & ~ins))
                return (cal, obj, staging, proc + jnp.sum(cnt_w), lv + lv_w,
                        late + jnp.sum(late_w.astype(jnp.int32)),
                        oob + jnp.sum(oob_w.astype(jnp.int32)),
                        covf + covf_w, sovf + sovf_w)
            return run

        for w in range(1, W + 1):
            carry = jax.lax.cond(w <= w_eff, sub_epoch(w), lambda c: c, carry)
        (cal_sp, obj_sp, staging, spec_proc, spec_lv, spec_late, spec_oob,
         spec_covf, stage_ovf) = carry

        # -- 4. the two exchanges (unconditional: collectives stay out of
        #       the commit/abort branches) ---------------------------------
        routed_safe = router.exchange(safe_buf, pl, cfg)

        ep_st = epoch_of(staging.ts, cfg.epoch_len)
        stage_remote = staging.valid & (pl.owner(staging.dst) != dev)
        # remote staged events up to the post-commit horizon ride the spec
        # exchange — including window-epoch stragglers, whose *arrival* is
        # exactly what the owner's violation count detects.
        spec_eligible = stage_remote & (ep_st <= e0 + w_eff + N)
        spec_buf, spec_send, spec_route_ovf = router.select_send(
            staging, spec_eligible, pl, cfg)
        routed_spec = router.exchange(spec_buf, pl, cfg)

        # -- 5. straggler detection: psum-replicated verdict ----------------
        def violations(batch: EventBatch) -> jax.Array:
            ep = epoch_of(batch.ts, cfg.epoch_len)
            mine = (batch.valid & (batch.dst >= 0) & (batch.dst < O)
                    & (pl.owner(batch.dst) == dev))
            return jnp.sum((mine & (ep <= e0 + w_eff)).astype(jnp.int32))

        # a staged/spec-routed event the buffers couldn't carry must abort:
        # parking it for a later epoch could make it LATE (dropped), and a
        # conservative engine never drops — the abort re-emits it instead.
        v_local = (violations(routed_safe) + violations(routed_spec)
                   + stage_ovf + spec_route_ovf)
        V = jax.lax.psum(v_local, AXIS)

        # -- 6. commit or roll back (local ops only) ------------------------
        def commit(_):
            cur_c = e0 + w_eff
            c, f, co1, fo1, l1, _ = deliver(
                cal_sp, fb, routed_safe, cur_c, dev, pl, cfg, init=False,
                replicated=router.replicated)
            c, f, co2, fo2, l2, _ = deliver(
                c, f, routed_spec, cur_c, dev, pl, cfg, init=False,
                replicated=router.replicated)
            # staged leftovers: local beyond the window → deliver (insert or
            # park); remote beyond the post-commit horizon → fallback, to
            # re-offer through routing on later epochs.
            leftover = staging.valid & ~spec_send
            lo_local = leftover & (pl.owner(staging.dst) == dev)
            c, f, co3, fo3, l3, _ = deliver(
                c, f, staging._replace(valid=lo_local), cur_c, dev, pl, cfg,
                init=False, replicated=False)
            f, fo4 = fallback_put(
                f, staging._replace(valid=leftover & ~lo_local))
            deltas = (spec_proc, spec_lv, spec_late, spec_oob,
                      spec_covf + co1 + co2 + co3, fo1 + fo2 + fo3 + fo4,
                      l1 + l2 + l3, zero,
                      jnp.where(dev == 0, 1, 0).astype(jnp.int32),
                      spec_proc)
            return c, f, obj_sp, e0 + w_eff + 1, deltas

        def abort(_):
            c = put_buckets(cal_sp, e0 + 1, shadow_cal)
            c, f, co, fo, l, _ = deliver(
                c, fb, routed_safe, e0, dev, pl, cfg, init=False,
                replicated=router.replicated)
            deltas = (zero, zero, zero, zero, co, fo, l,
                      jnp.where(dev == 0, 1, 0).astype(jnp.int32),
                      zero, zero)
            return c, f, shadow_obj, e0 + 1, deltas

        cal_f, fb_f, obj_f, e_next, deltas = jax.lax.cond(
            V == 0, commit, abort, None)
        (d_proc, d_lv, d_late, d_oob, d_covf, d_fovf, d_l2,
         d_rb, d_cm, d_spec) = deltas

        st = state.stats
        stats = st._replace(
            processed=st.processed + proc0 + d_proc,
            cal_overflow=st.cal_overflow + cal_ovf0 + d_covf,
            fb_overflow=st.fb_overflow + fb_ovf0 + fb_ovf0b + d_fovf,
            route_overflow=st.route_overflow + route_ovf0,
            late_events=st.late_events + n_late0 + late0b + d_late + d_l2,
            lookahead_violations=st.lookahead_violations + lv0 + d_lv,
            oob_events=st.oob_events + n_oob0 + d_oob,
            rollbacks=st.rollbacks + d_rb,
            speculated=st.speculated + d_spec,
            spec_commits=st.spec_commits + d_cm,
        )
        return EngineState(cal_f, fb_f, obj_f,
                           jnp.reshape(e_next, state.epoch.shape), stats,
                           state.bounds, state.load)

    return step
