"""StealPolicy stage implementations (paper §II-A).

``none`` — stage 2 is a no-op: the scheduler processes exactly the local
           extract.
``loan`` — epoch-granular batch loans: overloaded devices publish their
           hottest objects' (state + current-epoch batch); a deterministic
           plan computed replicated from the gathered load vector assigns
           each loan to an underloaded receiver; receivers process loans
           alongside their own batches and return the updated state.
           Ownership (calendars, future insertions) never moves.

The combinatorial loan math (donor selection, replicated planning) stays in
:mod:`repro.core.stealing`; this module is the pipeline-facing policy that
wires it around the processing stage.  The loan path processes through the
rounds-family schedulers (dense ``batch`` rounds or the width-packed tiles)
— loaned batches are concatenated onto the local extract as extra rows,
which a model-specific whole-batch kernel can't ingest (EngineConfig fails
fast on that combination).

Composition with speculation (``opt_window > 0``, pipeline/speculate.py):
loans run inside speculative sub-epochs too, but ONLY under the global
all-or-nothing vote (``opt_commit='global'``).  A loaned batch executes on
the *borrower*: its staged emissions sit in the borrower's staging buffer
and would commit with the borrower's verdict, while a straggler at the
*owner* re-executes the same batch after rollback — a per-device verdict
could deliver those emissions twice.  The global vote makes every window
atomic across devices, so the loan's emissions exist exactly once whichever
branch runs.  EngineConfig rejects ``steal=True`` with
``opt_commit='device'`` fail-fast.  The ``all_gather``s below are legal
inside the speculation stage's ``lax.cond`` because the window predicate is
replicated — every device takes the same branch in the same iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import stealing as steal_mod
from .base import AXIS, StealPolicy, register_steal_policy


@register_steal_policy("none")
class NoSteal(StealPolicy):
    """Process the local extract as-is (also used whenever D == 1)."""

    def process(self, model, scheduler, cfg, placement, dev, obj, ts_s,
                seed_s, pay_s, cnt_b):
        obj, out_flat, lv = scheduler.process(model, cfg, obj, ts_s, seed_s,
                                              pay_s, cnt_b)
        return obj, out_flat, lv, jnp.int32(0), jnp.sum(cnt_b)


@register_steal_policy("loan")
class LoanSteal(StealPolicy):
    """Publish loans, claim, process augmented batches, return state."""

    def process(self, model, scheduler, cfg, placement, dev, obj, ts_s,
                seed_s, pay_s, cnt_b):
        # loans ride the rounds-family schedulers (see module docstring);
        # EngineConfig fails fast if steal=True is combined with a scheduler
        # that can't ingest the loan-augmented rows.
        D = placement.n_devices
        boundaries = jnp.asarray(placement.boundaries, jnp.int32)

        load = jnp.sum(cnt_b)
        loads = jax.lax.all_gather(load, AXIS)                     # [D]
        total = jnp.sum(loads)
        target = (total + D - 1) // D

        top_idx, top_w, loan_valid = steal_mod.select_loans(
            cnt_b, load, target, cfg.steal_cap)

        pub = {
            "state": steal_mod.gather_rows(obj, top_idx),
            "ts": ts_s[top_idx], "seed": seed_s[top_idx],
            "pay": pay_s[top_idx],
            "cnt": top_w, "gid": top_idx + boundaries[dev],
            "valid": loan_valid,
        }
        pub_g = jax.tree.map(lambda x: jax.lax.all_gather(x, AXIS), pub)

        plan = steal_mod.plan_loans(loads, pub_g["cnt"], pub_g["valid"],
                                    cfg.claim_cap)

        # donor side: claimed loans are processed remotely — zero them here.
        own_claimed = plan.claimed.reshape(D, cfg.steal_cap)[dev]
        cnt_b = cnt_b.at[top_idx].set(
            jnp.where(own_claimed & loan_valid, 0, cnt_b[top_idx]))

        # receiver side: compact my claims into claim_cap rows.
        claim_mask = plan.claimed & (plan.assignee == dev)         # [D*sc]
        corder = jnp.argsort(~claim_mask, stable=True)[:cfg.claim_cap]
        cvalid = claim_mask[corder]
        flat = lambda l: l.reshape((D * cfg.steal_cap,) + l.shape[2:])
        cl_state = jax.tree.map(lambda l: flat(l)[corder], pub_g["state"])
        cl_ts = flat(pub_g["ts"])[corder]
        cl_seed = flat(pub_g["seed"])[corder]
        cl_pay = flat(pub_g["pay"])[corder]
        cl_cnt = jnp.where(cvalid, flat(pub_g["cnt"])[corder], 0)
        cl_gid = flat(pub_g["gid"])[corder]

        n_local = cnt_b.shape[0]
        obj_aug = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                               obj, cl_state)
        ts_aug = jnp.concatenate([ts_s, cl_ts], axis=0)
        seed_aug = jnp.concatenate([seed_s, cl_seed], axis=0)
        pay_aug = jnp.concatenate([pay_s, cl_pay], axis=0)
        cnt_aug = jnp.concatenate([cnt_b, cl_cnt], axis=0)

        obj_aug, out_flat, lv = scheduler.process(
            model, cfg, obj_aug, ts_aug, seed_aug, pay_aug, cnt_aug)
        obj = jax.tree.map(lambda l: l[:n_local], obj_aug)
        ret_state = jax.tree.map(lambda l: l[n_local:], obj_aug)

        ret = {"state": ret_state, "gid": cl_gid, "valid": cvalid}
        ret_g = jax.tree.map(lambda x: jax.lax.all_gather(x, AXIS), ret)
        rgid = ret_g["gid"].reshape(-1)
        rvalid = ret_g["valid"].reshape(-1)
        rmine = rvalid & (placement.owner(rgid) == dev)
        lidx = jnp.clip(rgid - boundaries[dev], 0, n_local - 1)
        rstate = jax.tree.map(lambda l: l.reshape((-1,) + l.shape[2:]),
                              ret_g["state"])
        obj = steal_mod.scatter_rows(obj, lidx, rstate, rmine)

        proc_count = jnp.sum(cnt_b) + jnp.sum(cl_cnt)
        return obj, out_flat, lv, jnp.sum(cvalid.astype(jnp.int32)), proc_count
