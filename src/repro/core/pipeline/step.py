"""The per-device epoch step: pure wiring of the pipeline stages.

    extract → steal → process → route → deliver  (+ stats accumulation)

Stage behavior lives behind the :mod:`repro.core.pipeline.base` interfaces;
:func:`make_step` resolves the configured Scheduler / Router / StealPolicy
once, runs their fail-fast validation, and returns the jittable step closure
the engine shard_maps over the mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..api import SimModel
from ..calendar import Fallback, extract_sorted
from ..events import compact_mask, concat_batches, truncate
from ..placement import Placement
from . import routers, schedulers, steal  # noqa: F401  (registration imports)
from .base import (AXIS, EngineState, Stats, epoch_of, resolve_router,
                   resolve_scheduler, resolve_steal)
from .config import EngineConfig
from .deliver import deliver


def make_step(model: SimModel, cfg: EngineConfig, placement: Placement
              ) -> Callable[[EngineState], EngineState]:
    D = placement.n_devices
    N = cfg.n_buckets

    scheduler = resolve_scheduler(cfg)
    router = resolve_router(cfg.route)
    policy = resolve_steal(cfg, D)
    scheduler.validate(model, cfg)
    router.validate(cfg, placement)

    def step(state: EngineState) -> EngineState:
        dev = jax.lax.axis_index(AXIS)
        cur = state.epoch[0]

        # 1. extract — drain the calendar bucket of the current epoch.
        cal, ts_s, seed_s, pay_s, cnt_b = extract_sorted(state.cal, cur)

        # 2.+3. steal + process — the policy runs the scheduler (possibly on
        # loan-augmented batches) and reports emitted events + counts.
        obj, out_flat, lv, stolen, proc_count = policy.process(
            model, scheduler, cfg, placement, dev, state.obj,
            ts_s, seed_s, pay_s, cnt_b)

        # 4. route — producer-side triage (fresh events + fallback entries),
        # selection against the route capacity, then the exchange collective.
        prod = concat_batches(out_flat, state.fb.events)
        epochs = epoch_of(prod.ts, cfg.epoch_len)
        eligible = prod.valid & (epochs >= cur + 1) & (epochs <= cur + N)
        late_prod = prod.valid & (epochs <= cur)
        n_late_prod = jnp.sum(late_prod.astype(jnp.int32))

        route_buf, send, route_ovf = router.select_send(prod, eligible,
                                                        placement, cfg)

        keep = prod.valid & ~send & ~late_prod
        kept = compact_mask(prod, keep)
        fb = Fallback(truncate(kept, cfg.fallback_cap))
        fb_ovf = jnp.sum(kept.valid[cfg.fallback_cap:].astype(jnp.int32))

        routed = router.exchange(route_buf, placement, cfg)

        # 5. deliver — owners insert into calendar buckets / fallback.
        cal, fb, cal_ovf, fb_ovf2, late2 = deliver(
            cal, fb, routed, cur, dev, placement, cfg, init=False)

        st = state.stats
        stats = Stats(
            processed=st.processed + proc_count,
            cal_overflow=st.cal_overflow + cal_ovf,
            fb_overflow=st.fb_overflow + fb_ovf + fb_ovf2,
            route_overflow=st.route_overflow + route_ovf,
            late_events=st.late_events + n_late_prod + late2,
            lookahead_violations=st.lookahead_violations + lv,
            stolen=st.stolen + stolen,
        )
        return EngineState(cal, fb, obj, state.epoch + 1, stats)

    return step
