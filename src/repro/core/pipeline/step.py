"""The per-device epoch step: pure wiring of the pipeline stages.

    extract → steal → process → rebalance → route → deliver  (+ stats)

Stage behavior lives behind the :mod:`repro.core.pipeline.base` interfaces;
:func:`make_step` resolves the configured Scheduler / Router / StealPolicy /
RebalancePolicy once, runs their fail-fast validation, and returns the
jittable step closure the engine shard_maps over the mesh.  The process
stage receives the live :class:`EngineConfig` (schedulers read their knobs —
``lookahead``, the width-packer's ``pack_tile`` — off it), so the wiring
here stays knob-free.

Placement boundaries are *state*, not trace constants: every step rebuilds a
runtime :class:`~repro.core.placement.Placement` from ``state.bounds`` so the
adaptive rebalance stage can move the cuts at epoch boundaries.  The
rebalance runs between process and route — the epoch's fresh emissions (and
every fallback re-offer) are routed against the new boundaries immediately.

Out-of-range destinations (``dst`` outside ``[0, n_objects)``) are triaged at
the producer: counted in ``stats.oob_events`` (a hard error at the driver,
like overflow) and excluded from routing/fallback, where the owner
searchsorted + local-index clip would otherwise deliver them into the wrong
object's calendar.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..api import SimModel
from ..calendar import Fallback, extract_sorted
from ..events import compact_mask, concat_batches, truncate
from ..placement import Placement
from . import rebalance, routers, schedulers, steal  # noqa: F401  (registration imports)
from .base import (AXIS, EngineState, epoch_of, resolve_rebalance,
                   resolve_router, resolve_scheduler, resolve_steal)
from .config import EngineConfig
from .deliver import deliver


def make_step(model: SimModel, cfg: EngineConfig, placement: Placement
              ) -> Callable[[EngineState], EngineState]:
    D = placement.n_devices
    N = cfg.n_buckets
    O = placement.n_objects

    scheduler = resolve_scheduler(cfg)
    router = resolve_router(cfg.route)
    policy = resolve_steal(cfg, D)
    rebalancer = resolve_rebalance(cfg)
    adaptive = cfg.placement == "adaptive"
    scheduler.validate(model, cfg)
    router.validate(cfg, placement)

    def step(state: EngineState) -> EngineState:
        dev = jax.lax.axis_index(AXIS)
        cur = state.epoch[0]
        pl = placement.with_boundaries(state.bounds[0])

        # 1. extract — drain the calendar bucket of the current epoch.
        cal, ts_s, seed_s, pay_s, cnt_b = extract_sorted(state.cal, cur)

        # 2.+3. steal + process — the policy runs the scheduler (possibly on
        # loan-augmented batches) and reports emitted events + counts.
        obj, out_flat, lv, stolen, proc_count = policy.process(
            model, scheduler, cfg, pl, dev, state.obj,
            ts_s, seed_s, pay_s, cnt_b)

        # 3b. rebalance — adaptive placement moves the boundaries and
        # migrates object rows at epoch boundaries; everything downstream
        # (routing, delivery) sees the new cuts.
        if adaptive:
            load = state.load + cnt_b
            bounds, load, cal, obj, migrated, fired = rebalancer.rebalance(
                cfg, placement, dev, cur, state.bounds[0], load, cal, obj)
            pl = placement.with_boundaries(bounds)
        else:
            bounds, load = state.bounds[0], state.load
            migrated = fired = jnp.int32(0)

        # 4. route — producer-side triage (fresh events + fallback entries),
        # selection against the route capacity, then the exchange collective.
        prod = concat_batches(out_flat, state.fb.events)
        epochs = epoch_of(prod.ts, cfg.epoch_len)
        oob = prod.valid & ((prod.dst < 0) | (prod.dst >= O))
        n_oob = jnp.sum(oob.astype(jnp.int32))
        eligible = prod.valid & ~oob & (epochs >= cur + 1) & (epochs <= cur + N)
        late_prod = prod.valid & ~oob & (epochs <= cur)
        n_late_prod = jnp.sum(late_prod.astype(jnp.int32))

        route_buf, send, route_ovf = router.select_send(prod, eligible,
                                                        pl, cfg)

        keep = prod.valid & ~send & ~late_prod & ~oob
        kept = compact_mask(prod, keep)
        fb = Fallback(truncate(kept, cfg.fallback_cap))
        fb_ovf = jnp.sum(kept.valid[cfg.fallback_cap:].astype(jnp.int32))

        routed = router.exchange(route_buf, pl, cfg)

        # 5. deliver — owners insert into calendar buckets / fallback.  The
        # router declares its output topology: a broadcast batch is counted
        # once globally, a per-device a2a slice is counted where it lands.
        cal, fb, cal_ovf, fb_ovf2, late2, oob2 = deliver(
            cal, fb, routed, cur, dev, pl, cfg, init=False,
            replicated=router.replicated)

        st = state.stats
        # the conservative step never speculates: rollbacks / speculated /
        # spec_commits ride through untouched (zero unless opt_window > 0,
        # which routes to pipeline.speculate's step instead of this one).
        stats = st._replace(
            processed=st.processed + proc_count,
            cal_overflow=st.cal_overflow + cal_ovf,
            fb_overflow=st.fb_overflow + fb_ovf + fb_ovf2,
            route_overflow=st.route_overflow + route_ovf,
            late_events=st.late_events + n_late_prod + late2,
            lookahead_violations=st.lookahead_violations + lv,
            stolen=st.stolen + stolen,
            oob_events=st.oob_events + n_oob + oob2,
            rebalances=st.rebalances + fired,
            migrated=st.migrated + migrated,
        )
        return EngineState(cal, fb, obj, state.epoch + 1, stats,
                           bounds[None, :], load)

    return step
