"""Width-packing of the padded per-epoch calendar slice (paper §II-A).

The ``batch`` scheduler's rounds loop executes a dense ``[n_rows, C]`` grid:
round ``r`` vmaps :meth:`~repro.core.api.SimModel.process_event` over *every*
padded row, live or not, so the epoch costs ``max-per-object batch depth ×
padded row width`` regardless of how many events are actually present.  On a
skewed workload with uneven placement (wide ``n_local_max`` pad, one deep
object) almost every lane is wasted — the *padded-row tax* measured in
BENCH_pr3.json.

The packer compacts the slice into a dense work list ordered round-major,
row-minor — stable by ``(round, row)``, so an object's events keep their
(ts, seed)-sorted intra-object order and bit-exactness is preserved by
construction:

* within a round every object appears at most once, so a vmap tile drawn
  from a single round can gather per-object state, process, and scatter it
  back with no read-after-write conflict;
* each round's occupied slots are padded up to a multiple of the tile width,
  so no tile ever spans a round boundary;
* rounds appear in increasing order, so round ``r+1`` of an object is always
  processed in a strictly later tile than its round ``r`` — the scatter-back
  between tiles carries the state dependency.

Total work is ``sum_r ceil(occ_r / tile) * tile`` lanes — it scales with the
events present (plus per-round tile rounding), not with the worst-case grid.

The pack → unpack pair is a pure permutation of the live slots; the
hypothesis properties in ``tests/test_property.py`` pin the round-trip,
order- and multiset-preservation guarantees the scheduler relies on.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..calendar import group_ranks


class PackedSlice(NamedTuple):
    """A calendar slice compacted to a dense (round-major) work list.

    Slots ``[0, n_tiles * tile)`` are organized as ``n_tiles`` vmap tiles;
    each tile's events belong to one batch round (distinct rows).  Dead slots
    (per-round tile padding and everything past the live region) carry
    ``valid=False``, ``row = n_rows`` (a scatter-drop sentinel) and
    ``ts=+inf``.
    """

    ts: jax.Array       # f32 [k_pad]
    seed: jax.Array     # u32 [k_pad]
    payload: jax.Array  # f32 [k_pad]
    row: jax.Array      # i32 [k_pad] local object row (n_rows on dead slots)
    rnd: jax.Array      # i32 [k_pad] batch round (intra-object event index)
    valid: jax.Array    # bool [k_pad]
    n_tiles: jax.Array  # i32 scalar — live tiles (= padded total / tile)
    tile: int           # static effective tile width


def effective_tile(tile: int, n_rows: int) -> int:
    """Clamp the configured tile to the slice width (a tile wider than the
    row count would only re-buy the padded-grid lanes packing removes)."""
    return max(1, min(int(tile), n_rows)) if n_rows else 1


def pack_capacity(n_rows: int, cap: int, tile: int) -> int:
    """Static work-list capacity: every round padded to a full tile."""
    t = effective_tile(tile, n_rows)
    return cap * t * ((n_rows + t - 1) // t) if n_rows else 0


def pack_slice(ts_s: jax.Array, seed_s: jax.Array, pay_s: jax.Array,
               cnt_b: jax.Array, tile: int) -> PackedSlice:
    """Compact a sorted ``[n_rows, C]`` calendar slice into a PackedSlice.

    ``ts_s``/``seed_s``/``pay_s`` are :func:`repro.core.calendar.extract_sorted`
    outputs (row ``o``'s live events in columns ``[0, cnt_b[o])``, (ts, seed)-
    sorted).  Column ``r`` is round ``r``; event ``(o, r)`` lands at
    ``round_base[r] + rank-of-o-among-live-rows`` — a stable (round, row)
    ordering computed with prefix sums, no sort needed.
    """
    n_rows, cap = ts_s.shape
    t = effective_tile(tile, n_rows)
    k_pad = pack_capacity(n_rows, cap, tile)
    if k_pad == 0:
        return PackedSlice(
            ts=jnp.zeros((0,), jnp.float32), seed=jnp.zeros((0,), jnp.uint32),
            payload=jnp.zeros((0,), jnp.float32),
            row=jnp.zeros((0,), jnp.int32), rnd=jnp.zeros((0,), jnp.int32),
            valid=jnp.zeros((0,), bool), n_tiles=jnp.int32(0), tile=t)

    mask = (jnp.arange(cap, dtype=jnp.int32)[None, :]
            < cnt_b[:, None])                                  # [n_rows, cap]
    occ = jnp.sum(mask.astype(jnp.int32), axis=0)              # [cap]
    rank = jnp.cumsum(mask.astype(jnp.int32), axis=0) - 1      # [n_rows, cap]
    padded = ((occ + t - 1) // t) * t
    base = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    pos = base[None, :] + rank
    flat = jnp.where(mask, pos, k_pad).reshape(-1)             # drop sentinel

    def scat(init, vals):
        return init.at[flat].set(vals.reshape(-1), mode="drop")

    rows = jnp.broadcast_to(
        jnp.arange(n_rows, dtype=jnp.int32)[:, None], (n_rows, cap))
    rnds = jnp.broadcast_to(
        jnp.arange(cap, dtype=jnp.int32)[None, :], (n_rows, cap))
    return PackedSlice(
        ts=scat(jnp.full((k_pad,), jnp.inf, jnp.float32), ts_s),
        seed=scat(jnp.zeros((k_pad,), jnp.uint32), seed_s),
        payload=scat(jnp.zeros((k_pad,), jnp.float32), pay_s),
        row=scat(jnp.full((k_pad,), n_rows, jnp.int32), rows),
        rnd=scat(jnp.zeros((k_pad,), jnp.int32), rnds),
        valid=jnp.zeros((k_pad,), bool).at[flat].set(True, mode="drop"),
        n_tiles=jnp.sum(padded) // t,
        tile=t)


def unpack_slice(packed: PackedSlice, n_rows: int, cap: int):
    """Invert :func:`pack_slice` back to the ``[n_rows, cap]`` slice layout.

    Returns ``(ts, seed, payload, cnt)`` with each row's events front-packed
    in their original (round) order and dead slots at ``ts=+inf`` — exactly
    the :func:`~repro.core.calendar.extract_sorted` shape the packer consumed.
    The pair being an exact round-trip (the property suite pins this) is what
    makes "same bits, different schedule" an invariant rather than a hope.
    """
    order, ks, rank = group_ranks(packed.row, packed.valid, n_rows)
    valid_s = ks < n_rows
    dest = jnp.where(valid_s & (rank < cap), ks * cap + rank, n_rows * cap)

    def scat(init, vals):
        return init.reshape(-1).at[dest].set(
            vals[order], mode="drop").reshape(n_rows, cap)

    ts = scat(jnp.full((n_rows, cap), jnp.inf, jnp.float32), packed.ts)
    seed = scat(jnp.zeros((n_rows, cap), jnp.uint32), packed.seed)
    pay = scat(jnp.zeros((n_rows, cap), jnp.float32), packed.payload)
    cnt = jnp.zeros((n_rows,), jnp.int32).at[
        jnp.where(packed.valid, packed.row, n_rows)].add(1, mode="drop")
    return ts, seed, pay, cnt
