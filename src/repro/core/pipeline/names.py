"""Selectable stage names, importable without JAX.

Single source of truth for the *user-facing* choice sets of the pipeline
(`EngineConfig` fields, CLI ``choices=``).  The live registries in
:mod:`repro.core.pipeline.base` are populated by importing the stage modules
— which import JAX — so anything that must enumerate the choices in a
dependency-free context (the CI docs job, :mod:`repro.testing.docs_check`'s
CLI cross-check) reads this module instead.  ``tests/test_pipeline.py``
asserts these constants match the populated registries, so they cannot
silently drift.

This module must stay stdlib-only (no jax, no numpy): docs_check loads it
by file path in an environment with nothing installed.
"""
from __future__ import annotations

#: the ``scheduler='batch'`` family, split by ``EngineConfig.batch_impl``
#: (keys = selectable batch_impl values, values = internal registry names).
BATCH_IMPLS: dict[str, str] = {"rounds": "batch", "model": "batch-model",
                               "packed": "batch-packed"}

#: directly selectable ``EngineConfig.scheduler`` names (the internal
#: batch-family registry names are reached via ``batch_impl``, never named).
SELECTABLE_SCHEDULERS: tuple[str, ...] = ("batch", "ltf")

#: ``EngineConfig.route`` registry keys.
ROUTES: tuple[str, ...] = ("allgather", "a2a")

#: ``EngineConfig.placement`` values (paper §II-A/§II-C knapsacks).
PLACEMENTS: tuple[str, ...] = ("equal", "weighted", "adaptive")

#: ``EngineConfig`` fields of the bounded-optimism speculation stage
#: (Time Warp lite).  Every knob here must be exposed as a ``--opt-*`` CLI
#: flag by the simulate driver — :mod:`repro.testing.docs_check` derives the
#: required flag names from this tuple, so a new speculation knob that never
#: reaches the CLI fails the docs job.
#: (``inject_straggler_every`` is deliberately absent: it is a test-only
#: determinism harness, not a user-facing speculation knob.)
SPECULATION_KNOBS: tuple[str, ...] = ("opt_window", "opt_stage_cap",
                                      "opt_commit", "opt_adaptive")
