"""Sequential discrete-event oracle (numpy, heap-based).

Processes events one at a time in global ``(ts, seed)`` order — the classic
single-threaded DES loop.  Because all model randomness is counter-based, the
parallel PARSIR engine (any device count, any routing strategy, stealing on or
off) must produce the *identical* multiset of processed events and — with the
dyadic increment distribution — bit-identical object state.  This oracle is the
correctness anchor for every integration test.
"""
from __future__ import annotations

import heapq
from typing import Any

import numpy as np


class SequentialResult:
    def __init__(self, n_objects: int):
        self.processed_per_object = np.zeros(n_objects, np.int64)
        self.processed_records: list[tuple] = []  # (dst, seed) of processed events
        self.pending_records: list[tuple] = []    # (dst, seed) still in the heap
        self.obj_state: list[dict] | None = None

    @property
    def total_processed(self) -> int:
        return int(self.processed_per_object.sum())

    def records_sorted(self) -> np.ndarray:
        return _sorted_rec(self.processed_records)

    def pending_sorted(self) -> np.ndarray:
        """The multiset of un-processed events at the horizon, sorted.

        Counter-based RNG makes the whole event tree a pure function of the
        initial seeds, so a parallel run that processed the same *count* of
        events and left the same *pending* multiset necessarily processed the
        same record multiset — this is the engine-comparable face of
        ``processed_records`` (the engine keeps no processed log)."""
        return _sorted_rec(self.pending_records)


def _sorted_rec(records: list[tuple]) -> np.ndarray:
    rec = np.array(sorted(records), dtype=np.uint64)
    return rec.reshape(-1, 2) if rec.size else rec.reshape(0, 2)


def run_sequential(model: Any, n_epochs: int, epoch_len: float) -> SequentialResult:
    """Run until simulation time ``n_epochs * epoch_len`` (exclusive)."""
    horizon = np.float32(n_epochs) * np.float32(epoch_len)
    res = SequentialResult(model.n_objects)
    state = model.init_object_state_np(np.arange(model.n_objects))

    init = model.initial_events()
    heap: list[tuple] = []
    for dst, ts, seed, payload in zip(init["dst"], init["ts"], init["seed"],
                                      init["payload"]):
        heapq.heappush(heap, (np.float32(ts), int(seed), int(dst),
                              np.float32(payload)))

    while heap and heap[0][0] < horizon:
        ts, seed, dst, payload = heapq.heappop(heap)
        res.processed_per_object[dst] += 1
        res.processed_records.append((int(dst), int(seed)))
        out = model.process_event_np(state[dst], np.float32(ts),
                                     np.uint32(seed), np.float32(payload))
        heapq.heappush(heap, (np.float32(out["ts"]), int(out["seed"]),
                              int(out["dst"]), np.float32(out["payload"])))

    res.pending_records = [(int(dst), int(seed)) for _, seed, dst, _ in heap]
    res.obj_state = state
    return res
