"""Sequential discrete-event oracle (numpy, heap-based).

Processes events one at a time in global ``(ts, seed)`` order — the classic
single-threaded DES loop.  Because all model randomness is counter-based, the
parallel PARSIR engine (any device count, any routing strategy, stealing on or
off) must produce the *identical* multiset of processed events and — with the
dyadic increment distribution — bit-identical object state.  This oracle is the
correctness anchor for every integration test.

Event flow is variable-arity, mirroring the engine's generalized contract:
``process_event_np`` may return a single event dict (the legacy one-out
shape), a list of 0..``model.max_out`` event dicts (multi-emission / open
networks), or nothing at all (absorption — sinks).  Entries carrying
``valid: False`` are skipped, matching the engine's ``EmittedEvents.valid``
masks.
"""
from __future__ import annotations

import heapq
from typing import Any

import numpy as np


class SequentialResult:
    def __init__(self, n_objects: int):
        self.processed_per_object = np.zeros(n_objects, np.int64)
        self.processed_records: list[tuple] = []  # (dst, seed) of processed events
        self.pending_records: list[tuple] = []    # (dst, seed) still in the heap
        self.obj_state: list[dict] | None = None

    @property
    def total_processed(self) -> int:
        return int(self.processed_per_object.sum())

    def records_sorted(self) -> np.ndarray:
        return _sorted_rec(self.processed_records)

    def pending_sorted(self) -> np.ndarray:
        """The multiset of un-processed events at the horizon, sorted.

        Counter-based RNG makes the whole event tree a pure function of the
        initial seeds, so a parallel run that processed the same *count* of
        events and left the same *pending* multiset necessarily processed the
        same record multiset — this is the engine-comparable face of
        ``processed_records`` (the engine keeps no processed log)."""
        return _sorted_rec(self.pending_records)


def _sorted_rec(records: list[tuple]) -> np.ndarray:
    rec = np.array(sorted(records), dtype=np.uint64)
    return rec.reshape(-1, 2) if rec.size else rec.reshape(0, 2)


def as_emitted(out: Any) -> list[dict]:
    """Normalize a model's emitted events to a list of valid event dicts.

    Accepted shapes: ``None`` / ``[]`` (absorption), a single event dict
    (the legacy exactly-one-out contract), or a list of event dicts.  Events
    with an explicit ``valid: False`` are dropped — the numpy face of the
    engine's ``EmittedEvents.valid`` mask.
    """
    if out is None:
        return []
    if isinstance(out, dict):
        out = [out]
    return [e for e in out if e.get("valid", True)]


def run_sequential(model: Any, n_epochs: int, epoch_len: float,
                   seed: int | None = None) -> SequentialResult:
    """Run until simulation time ``n_epochs * epoch_len`` (exclusive).

    ``seed`` selects the replication's bootstrap stream, mirroring the
    engine's ``init(seed=...)`` (``None`` keeps the model's own default)."""
    horizon = np.float32(n_epochs) * np.float32(epoch_len)
    max_out = getattr(model, "max_out", 1)
    res = SequentialResult(model.n_objects)
    state = model.init_object_state_np(np.arange(model.n_objects))

    init = (model.initial_events() if seed is None
            else model.initial_events(seed))
    heap: list[tuple] = []
    for dst, ts, seed, payload in zip(init["dst"], init["ts"], init["seed"],
                                      init["payload"]):
        heapq.heappush(heap, (np.float32(ts), int(seed), int(dst),
                              np.float32(payload)))

    while heap and heap[0][0] < horizon:
        ts, seed, dst, payload = heapq.heappop(heap)
        res.processed_per_object[dst] += 1
        res.processed_records.append((int(dst), int(seed)))
        out = model.process_event_np(state[dst], np.float32(ts),
                                     np.uint32(seed), np.float32(payload))
        emitted = as_emitted(out)
        if len(emitted) > max_out:
            raise ValueError(
                f"model emitted {len(emitted)} events > max_out={max_out} — "
                "the engine's fixed-size emission buffers cannot represent "
                "this; raise the model's max_out")
        for e in emitted:
            heapq.heappush(heap, (np.float32(e["ts"]), int(e["seed"]),
                                  int(e["dst"]), np.float32(e["payload"])))

    res.pending_records = [(int(dst), int(seed)) for _, seed, dst, _ in heap]
    res.obj_state = state
    return res
