"""The application-facing API (paper §I).

PARSIR exposes exactly two services to model code::

    ScheduleNewEvent(...)   — inject a future event for any object
    ProcessEvent(...)       — callback: the model processes one event

The JAX-functional equivalent is the :class:`SimModel` protocol below.
``process_event`` is the ProcessEvent callback; the events it *returns* are the
ScheduleNewEvent calls (a functional engine can't accept callbacks mid-trace, so
scheduling is by return value — the ``EmittedEvents`` batch).  The engine vmaps
``process_event`` over all local objects (each applying its r-th in-order event
per round), which is the SPMD realization of the paper's per-object batch
processing.

Contract (the conservative-correctness obligations):
  * every emitted event must satisfy ``ts_out >= ts_in + lookahead`` — the
    engine counts violations (``stats.lookahead_violations``) and the driver
    refuses to continue on nonzero;
  * emitted dst are *global* object ids (the engine routes them);
  * all randomness must come from the event ``seed`` via ``core.events.fold``
    so results are independent of processing order and device count.
"""
from __future__ import annotations

import abc
from typing import Any, NamedTuple

import jax
import numpy as np


class EmittedEvents(NamedTuple):
    """Up to ``max_out`` events emitted while processing one event.

    Emission arity is variable: any subset of the ``max_out`` rows may be
    live, flagged by ``valid`` — every pipeline stage honors the mask.  A
    sink *absorbs* its input by returning an all-invalid row; a source or
    fork *fans out* by returning several valid rows (``max_out > 1``).  The
    numpy oracle mirror expresses the same contract as a list of event dicts
    (see :func:`repro.core.ref_engine.as_emitted`).
    """

    dst: jax.Array      # i32 [max_out] global object id
    ts: jax.Array       # f32 [max_out]
    seed: jax.Array     # u32 [max_out]
    payload: jax.Array  # f32 [max_out]
    valid: jax.Array    # bool [max_out]


class SimModel(abc.ABC):
    """A discrete-event simulation model runnable by the PARSIR engine."""

    #: maximum number of events a single ProcessEvent call can emit.  The
    #: engine sizes its per-epoch emission buffers by this, so it is a hard
    #: cap; actual emissions per event range over 0..max_out via the
    #: ``EmittedEvents.valid`` mask (0 = absorption, >1 = fan-out).
    max_out: int = 1

    @property
    @abc.abstractmethod
    def n_objects(self) -> int:
        ...

    @abc.abstractmethod
    def init_object_state(self, global_ids: np.ndarray) -> Any:
        """Per-object state pytree with leading dim ``len(global_ids)``."""

    @abc.abstractmethod
    def initial_events(self, seed: int | None = None) -> dict[str, np.ndarray]:
        """The model's bootstrap events as flat numpy arrays
        {dst:i32[K], ts:f32[K], seed:u32[K], payload:f32[K]}.

        ``seed`` selects the replication: implementations XOR
        :func:`repro.core.events.seed_salt_np` into their init constant, so
        replications share shapes/destinations but draw disjoint RNG streams.
        ``None`` defers to the model's own ``params.seed`` (default 0 — the
        historical, golden-pinned stream).  Initial *object state* is
        deliberately seed-independent: all downstream randomness is
        event-seed-driven, so salting the bootstrap events alone makes whole
        trajectories diverge.
        """

    def object_weights(self) -> np.ndarray | None:
        """Optional per-object expected-load hint, f64[n_objects].

        Consumed by ``EngineConfig(placement="weighted")`` (and as the
        starting point of ``"adaptive"``): the engine packs contiguous id
        ranges balancing this weight — the paper's NUMA knapsack objective.
        ``None`` (the default) means "no skew known"; the engine falls back
        to the equal split.  Any positive scale works — only ratios matter.
        """
        return None

    @abc.abstractmethod
    def process_event(self, state_slice: Any, ts: jax.Array, seed: jax.Array,
                      payload: jax.Array) -> tuple[Any, EmittedEvents]:
        """ProcessEvent callback for a single object/event (engine vmaps it)."""
