"""Work stealing (paper §II-A), adapted to SPMD as epoch-granular batch loans.

In PARSIR a thread that drains its local NUMA node's object counter starts
drawing object ids from remote nodes and processes those objects' current-epoch
batches through remote memory accesses.  TPU chips have no remote memory, so
the *loan* is explicit: because the lookahead closes the epoch's workload before
processing starts, per-device loads are known up front, and overloaded devices
publish (object state + current-epoch events) of their hottest objects; a
deterministic plan — computed identically on every device from the gathered
load vector, the SPMD replacement for the fetch_and_add counters — assigns each
loan to an underloaded receiver.  Receivers process loaned batches alongside
their own and return the updated state; emitted events flow through normal
routing.  Ownership (calendars, future insertions) never moves.

Everything is static-shape: ``steal_cap`` loans per donor, ``claim_cap`` claims
per receiver; unassigned loans are simply processed by their owner as usual.

This module is the combinatorial loan math (donor selection, replicated
planning, row gather/scatter); the pipeline stage that wires it around batch
processing is :class:`repro.core.pipeline.steal.LoanSteal`.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class LoanPlan(NamedTuple):
    # flat over D * steal_cap published loans
    assignee: jax.Array   # i32 [D*steal_cap] receiving device, or D if unassigned
    claimed: jax.Array    # bool [D*steal_cap] assigned AND within receiver claim_cap


def plan_loans(loads: jax.Array, loan_weight: jax.Array, loan_valid: jax.Array,
               claim_cap: int) -> LoanPlan:
    """Deterministic donor→receiver assignment, computed replicated.

    loads:       i32 [D]   per-device event load this epoch (post all_gather)
    loan_weight: i32 [D, steal_cap] event count of each published loan (0 if invalid)
    loan_valid:  bool [D, steal_cap]
    """
    D = loads.shape[0]
    total = jnp.sum(loads)
    target = (total + D - 1) // D
    deficit = jnp.maximum(0, target - loads)              # receiver capacity

    w = jnp.where(loan_valid, loan_weight, 0).reshape(-1)  # [D*steal_cap]
    cum_w = jnp.cumsum(w)                                  # inclusive
    cum_cap = jnp.cumsum(deficit)                          # [D]
    # loan j goes to the first receiver whose cumulative capacity covers it.
    assignee = jnp.searchsorted(cum_cap, cum_w, side="left").astype(jnp.int32)
    assignee = jnp.where(loan_valid.reshape(-1) & (assignee < D), assignee, D)

    # rank of each loan among those assigned to the same receiver.
    onehot = (assignee[:, None] == jnp.arange(D)[None, :]).astype(jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot
    my_rank = jnp.sum(rank * onehot, axis=1)
    claimed = (assignee < D) & (my_rank < claim_cap)
    return LoanPlan(assignee, claimed)


def select_loans(cnt_b: jax.Array, load: jax.Array, target: jax.Array,
                 steal_cap: int):
    """Per-donor choice of which objects to publish: its hottest objects, up to
    ``steal_cap``, only while the donor stays above the target load."""
    top_cnt, top_idx = jax.lax.top_k(cnt_b, steal_cap)
    # keep loaning only while the running surplus remains positive.
    surplus = load - target
    shipped = jnp.cumsum(top_cnt) - top_cnt   # exclusive prefix
    valid = (top_cnt > 0) & (surplus > 0) & (shipped < surplus)
    return top_idx.astype(jnp.int32), jnp.where(valid, top_cnt, 0), valid


def gather_rows(tree: Any, idx: jax.Array) -> Any:
    return jax.tree.map(lambda l: l[idx], tree)


def scatter_rows(tree: Any, idx: jax.Array, rows: Any, mask: jax.Array) -> Any:
    def put(l, r):
        safe_idx = jnp.where(mask, idx, l.shape[0])
        return l.at[safe_idx].set(r, mode="drop")
    return jax.tree.map(put, tree, rows)
