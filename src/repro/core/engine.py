"""The PARSIR epoch-synchronous conservative engine (paper §II), in JAX.

One SPMD program instance per mesh device plays the role of a PARSIR worker
thread pinned to a CPU; a device's HBM plays the NUMA node.  An engine step
processes exactly one epoch through the stage pipeline of
:mod:`repro.core.pipeline`:

  1. **extract** — drain the calendar bucket of the current epoch for all local
     objects, sorted per object by (ts, seed) (lock-free: exclusive ownership,
     see calendar.py);
  2. **steal (optional)** — epoch-granular loans of hot objects from overloaded
     to underloaded devices (``StealPolicy``), decided from the globally
     gathered load vector (possible because the lookahead closes the epoch's
     workload);
  3. **process** — the per-object *batch* execution at the heart of the paper
     (``Scheduler``): round r applies the r-th in-order event of every object
     in parallel (vmap), so each object's state stays register/VMEM-hot across
     its whole batch while objects are processed in parallel;
  4. **route** — emitted events plus drained fallback entries are exchanged
     (``Router``: `allgather` mirrors the shared-memory "any thread enqueues
     anywhere" semantics; `a2a` is the optimized pairwise exchange);
  5. **deliver** — owners insert routed events into calendar buckets (conflict-
     free scatter) or park beyond-horizon events in the fallback buffer;
  6. **barrier** — implicit in the collectives; epoch advances everywhere.

Event flow is variable-arity end to end: each processed event emits
0..``model.max_out`` successors (``EmittedEvents`` rows with ``valid`` masks
honored at every stage), so open networks — sources fanning out, sinks
absorbing — run through the same pipeline as the classic one-in/one-out
workloads.

All capacities are static; every overflow/causality condition is *counted* in
``Stats`` and surfaced — a conservative engine must never silently drop or
reorder, so drivers (and tests) assert these counters stay zero.

This module is the user-facing wrapper (:class:`ParsirEngine`: mesh setup,
sharding, lifecycle) and re-exports the pipeline's stable names
(``EngineConfig``, ``EngineState``, ``Stats``, ``AXIS``, ``make_step``) so
historical ``repro.core.engine`` imports keep working.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .api import SimModel
from .calendar import make_calendar, make_fallback
from .events import EventBatch
from .pipeline import (AXIS, EngineConfig, EngineState, Stats, deliver,
                       make_step, zero_stats)
from .placement import equal_placement

__all__ = ["AXIS", "EngineConfig", "EngineState", "ParsirEngine", "Stats",
           "make_step", "zero_stats"]


def _shard_map(f, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


class ParsirEngine:
    """Build, initialize and run a PARSIR simulation on a device mesh."""

    def __init__(self, model: SimModel, cfg: EngineConfig,
                 mesh: Mesh | None = None):
        if mesh is None:
            mesh = Mesh(np.array(jax.devices()[:1]), (AXIS,))
        self.model, self.cfg, self.mesh = model, cfg, mesh
        D = int(np.prod(mesh.devices.shape))
        if model.n_objects % D:
            raise ValueError(f"n_objects={model.n_objects} not divisible by "
                             f"mesh size {D}")
        self.placement = equal_placement(model.n_objects, D)
        self.D = D

        self._step = make_step(model, cfg, self.placement)
        spec = P(AXIS)
        self._sharding = NamedSharding(mesh, spec)
        self._step_sm = jax.jit(_shard_map(self._step, mesh, (spec,), spec),
                                donate_argnums=0)
        self._run_cache: dict[int, Any] = {}

        def ingest(state: EngineState, batch: EventBatch) -> EngineState:
            dev = jax.lax.axis_index(AXIS)
            cur = state.epoch[0]
            cal, fb, cal_ovf, fb_ovf, late = deliver(
                state.cal, state.fb, batch, cur, dev, self.placement, cfg,
                init=True)
            st = state.stats
            stats = st._replace(cal_overflow=st.cal_overflow + cal_ovf,
                                fb_overflow=st.fb_overflow + fb_ovf,
                                late_events=st.late_events + late)
            return EngineState(cal, fb, state.obj, state.epoch, stats)

        self._ingest = jax.jit(_shard_map(ingest, mesh, (spec, P()), spec))

    # -- lifecycle -------------------------------------------------------------

    def init(self) -> EngineState:
        O, D = self.model.n_objects, self.D
        cfg = self.cfg
        obj_np = self.model.init_object_state(np.arange(O))
        obj = jax.tree.map(
            lambda l: jax.device_put(l, self._sharding), obj_np)
        cal = make_calendar(O, cfg.n_buckets, cfg.bucket_cap)
        cal = jax.tree.map(lambda l: jax.device_put(l, self._sharding), cal,
                           is_leaf=lambda x: isinstance(x, jax.Array))
        fb = make_fallback(D * cfg.fallback_cap)
        fb = jax.tree.map(lambda l: jax.device_put(l, self._sharding), fb,
                          is_leaf=lambda x: isinstance(x, jax.Array))
        epoch = jax.device_put(jnp.zeros((D,), jnp.int32), self._sharding)
        stats = jax.tree.map(
            lambda l: jax.device_put(jnp.tile(l, D), self._sharding),
            zero_stats())
        state = EngineState(cal, fb, obj, epoch, stats)

        init_ev = self.model.initial_events()
        batch = EventBatch(
            dst=jnp.asarray(init_ev["dst"], jnp.int32),
            ts=jnp.asarray(init_ev["ts"], jnp.float32),
            seed=jnp.asarray(init_ev["seed"], jnp.uint32),
            payload=jnp.asarray(init_ev["payload"], jnp.float32),
            valid=jnp.ones((len(init_ev["dst"]),), bool),
        )
        return self._ingest(state, batch)

    def step(self, state: EngineState) -> EngineState:
        return self._step_sm(state)

    def run(self, state: EngineState, n_epochs: int) -> EngineState:
        if n_epochs not in self._run_cache:
            def run_n(s):
                def body(s, _):
                    return self._step(s), ()
                s, _ = jax.lax.scan(body, s, None, length=n_epochs)
                return s
            spec = P(AXIS)
            self._run_cache[n_epochs] = jax.jit(
                _shard_map(run_n, self.mesh, (spec,), spec), donate_argnums=0)
        return self._run_cache[n_epochs](state)

    # -- inspection -------------------------------------------------------------

    def totals(self, state: EngineState) -> dict[str, int]:
        st = jax.tree.map(lambda l: int(np.sum(np.asarray(l))), state.stats)
        return st._asdict()

    def in_flight(self, state: EngineState) -> int:
        cal = int(np.sum(np.asarray(state.cal.cnt)))
        fb = int(np.sum(np.asarray(state.fb.events.valid)))
        return cal + fb
