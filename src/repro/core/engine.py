"""The PARSIR epoch-synchronous conservative engine (paper §II), in JAX.

One SPMD program instance per mesh device plays the role of a PARSIR worker
thread pinned to a CPU; a device's HBM plays the NUMA node.  An engine step
processes exactly one epoch:

  1. **extract** — drain the calendar bucket of the current epoch for all local
     objects, sorted per object by (ts, seed) (lock-free: exclusive ownership,
     see calendar.py);
  2. **steal (optional)** — epoch-granular loans of hot objects from overloaded
     to underloaded devices (stealing.py), decided from the globally gathered
     load vector (possible because the lookahead closes the epoch's workload);
  3. **process** — the per-object *batch* execution at the heart of the paper:
     round r applies the r-th in-order event of every object in parallel
     (vmap), so each object's state stays register/VMEM-hot across its whole
     batch while objects are processed in parallel;
  4. **route** — emitted events plus drained fallback entries are exchanged
     (`allgather` mirrors the shared-memory "any thread enqueues anywhere"
     semantics; `a2a` is the optimized pairwise exchange);
  5. **deliver** — owners insert routed events into calendar buckets (conflict-
     free scatter) or park beyond-horizon events in the fallback buffer;
  6. **barrier** — implicit in the collectives; epoch advances everywhere.

All capacities are static; every overflow/causality condition is *counted* in
``Stats`` and surfaced — a conservative engine must never silently drop or
reorder, so drivers (and tests) assert these counters stay zero.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import stealing as steal_mod
from .api import SimModel
from .calendar import (Calendar, Fallback, extract_sorted, fallback_put, insert,
                       make_calendar, make_fallback)
from .events import (EventBatch, compact_mask, concat_batches, empty_batch,
                     truncate)
from .placement import Placement, equal_placement

AXIS = "workers"


def _shard_map(f, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    lookahead: float                 # model lookahead L
    epoch_len: float | None = None   # defaults to L; may be a fraction of it
    n_buckets: int = 8               # N — calendar epochs in flight
    bucket_cap: int = 128            # events per (object, bucket)
    route_cap: int = 4096            # outgoing events per device per epoch
    fallback_cap: int = 4096         # per-device fallback list capacity
    route: str = "allgather"         # allgather | a2a  (identity when D == 1)
    scheduler: str = "batch"         # batch (PARSIR) | ltf (lowest-ts-first)
    batch_impl: str = "rounds"       # rounds (vmap) | model (Pallas kernel)
    steal: bool = False
    steal_cap: int = 4               # loans a donor may publish per epoch
    claim_cap: int = 4               # loans a receiver may claim per epoch

    def __post_init__(self):
        el = self.epoch_len if self.epoch_len is not None else self.lookahead
        if el > self.lookahead + 1e-9:
            raise ValueError("epoch_len must be <= lookahead (conservative)")
        object.__setattr__(self, "epoch_len", el)


class Stats(NamedTuple):
    processed: jax.Array             # events processed on this device
    cal_overflow: jax.Array          # bucket-capacity overflows (must be 0)
    fb_overflow: jax.Array           # fallback-capacity overflows (must be 0)
    route_overflow: jax.Array        # route-capacity overflows (must be 0)
    late_events: jax.Array           # causality violations (must be 0)
    lookahead_violations: jax.Array  # model emitted ts < ts_in + L (must be 0)
    stolen: jax.Array                # loaned batches processed on this device


def zero_stats() -> Stats:
    z = jnp.zeros((1,), jnp.int32)
    return Stats(z, z, z, z, z, z, z)


class EngineState(NamedTuple):
    cal: Calendar
    fb: Fallback
    obj: Any
    epoch: jax.Array   # i32 [1] per device (identical everywhere)
    stats: Stats


def _epoch_of(ts: jax.Array, epoch_len: float) -> jax.Array:
    return jnp.floor(ts * jnp.float32(1.0 / epoch_len)
                     if math.log2(1.0 / epoch_len).is_integer()
                     else ts / jnp.float32(epoch_len)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# per-object batch processing (paper §II-A)
# ---------------------------------------------------------------------------

def _process_batch(model: SimModel, obj: Any, ts_s, seed_s, pay_s, cnt_b,
                   lookahead: float):
    """Round r applies the r-th (ts,seed)-ordered event of every object."""
    n_rows, C = ts_s.shape
    mo = model.max_out
    out0 = EventBatch(
        dst=jnp.zeros((C, n_rows, mo), jnp.int32),
        ts=jnp.full((C, n_rows, mo), jnp.inf, jnp.float32),
        seed=jnp.zeros((C, n_rows, mo), jnp.uint32),
        payload=jnp.zeros((C, n_rows, mo), jnp.float32),
        valid=jnp.zeros((C, n_rows, mo), bool),
    )

    def body(r, carry):
        obj, out, lv = carry
        ets = jax.lax.dynamic_index_in_dim(ts_s, r, axis=1, keepdims=False)
        eseed = jax.lax.dynamic_index_in_dim(seed_s, r, axis=1, keepdims=False)
        epay = jax.lax.dynamic_index_in_dim(pay_s, r, axis=1, keepdims=False)
        m = r < cnt_b
        new_obj, emitted = jax.vmap(model.process_event)(obj, ets, eseed, epay)

        def sel(n, o):
            mm = m.reshape(m.shape + (1,) * (n.ndim - 1))
            return jnp.where(mm, n, o)

        obj = jax.tree.map(sel, new_obj, obj)
        ev_valid = emitted.valid & m[:, None]
        lv = lv + jnp.sum((ev_valid
                           & (emitted.ts < ets[:, None] + jnp.float32(lookahead))
                           ).astype(jnp.int32))
        out = EventBatch(
            dst=out.dst.at[r].set(emitted.dst),
            ts=out.ts.at[r].set(jnp.where(ev_valid, emitted.ts, jnp.inf)),
            seed=out.seed.at[r].set(emitted.seed),
            payload=out.payload.at[r].set(emitted.payload),
            valid=out.valid.at[r].set(ev_valid),
        )
        return obj, out, lv

    max_r = jnp.max(cnt_b) if n_rows else jnp.int32(0)
    obj, out, lv = jax.lax.fori_loop(
        0, max_r, body, (obj, out0, jnp.int32(0)))
    flat = EventBatch(*(x.reshape(-1) for x in out))
    return obj, flat, lv


def _process_ltf(model: SimModel, obj: Any, ts_s, seed_s, pay_s, cnt_b,
                 lookahead: float):
    """Comparison scheduler: strict lowest-timestamp-first interleaving across
    objects (ROOT-Sim/USE-style), one event at a time — same results, no batch
    locality.  Used by the Fig-5 analogue benchmark."""
    n_rows, C = ts_s.shape
    mo = model.max_out
    rows = jnp.broadcast_to(jnp.arange(n_rows, dtype=jnp.int32)[:, None],
                            (n_rows, C)).reshape(-1)
    live = (jnp.arange(C, dtype=jnp.int32)[None, :] < cnt_b[:, None]).reshape(-1)
    ts_f = jnp.where(live, ts_s.reshape(-1), jnp.inf)
    seed_f, pay_f = seed_s.reshape(-1), pay_s.reshape(-1)

    p1 = jnp.argsort(seed_f, stable=True)
    p2 = jnp.argsort(ts_f[p1], stable=True)
    order = p1[p2]
    ts_f, seed_f, pay_f = ts_f[order], seed_f[order], pay_f[order]
    rows, live = rows[order], live[order]

    K = n_rows * C
    out0 = EventBatch(
        dst=jnp.zeros((K, mo), jnp.int32),
        ts=jnp.full((K, mo), jnp.inf, jnp.float32),
        seed=jnp.zeros((K, mo), jnp.uint32),
        payload=jnp.zeros((K, mo), jnp.float32),
        valid=jnp.zeros((K, mo), bool),
    )

    def body(i, carry):
        obj, out, lv = carry
        row = rows[i]
        st = jax.tree.map(lambda l: l[row], obj)
        new_st, emitted = model.process_event(st, ts_f[i], seed_f[i], pay_f[i])
        obj = jax.tree.map(lambda l, n: l.at[row].set(n), obj, new_st)
        lv = lv + jnp.sum((emitted.valid
                           & (emitted.ts < ts_f[i] + jnp.float32(lookahead))
                           ).astype(jnp.int32))
        out = EventBatch(
            dst=out.dst.at[i].set(emitted.dst),
            ts=out.ts.at[i].set(jnp.where(emitted.valid, emitted.ts, jnp.inf)),
            seed=out.seed.at[i].set(emitted.seed),
            payload=out.payload.at[i].set(emitted.payload),
            valid=out.valid.at[i].set(emitted.valid),
        )
        return obj, out, lv

    total = jnp.sum(cnt_b)
    obj, out, lv = jax.lax.fori_loop(0, total, body, (obj, out0, jnp.int32(0)))
    flat = EventBatch(*(x.reshape(-1) for x in out))
    return obj, flat, lv


# ---------------------------------------------------------------------------
# delivery (insertion at the owner) — paper §II-B
# ---------------------------------------------------------------------------

def _deliver(cal: Calendar, fb: Fallback, batch: EventBatch, cur, dev,
             placement: Placement, cfg: EngineConfig, init: bool):
    """Insert my in-horizon events; park my beyond-horizon events in fallback."""
    N = cfg.n_buckets
    epochs = _epoch_of(batch.ts, cfg.epoch_len)
    boundaries = jnp.asarray(placement.boundaries, jnp.int32)
    owner = placement.owner(batch.dst)
    mine = batch.valid & (owner == dev)
    lo = jnp.int32(0) if init else cur + 1
    hi = cur + (N - 1 if init else N)
    insertable = mine & (epochs >= lo) & (epochs <= hi)
    beyond = mine & (epochs > hi)
    late = jnp.sum((mine & (epochs < lo)).astype(jnp.int32))

    local_idx = jnp.clip(batch.dst - boundaries[dev], 0, cal.n_local - 1)
    cal, cal_ovf = insert(cal, local_idx, epochs, batch.ts, batch.seed,
                          batch.payload, insertable)
    fb, fb_ovf = fallback_put(fb, EventBatch(batch.dst, batch.ts, batch.seed,
                                             batch.payload, beyond))
    return cal, fb, cal_ovf, fb_ovf, late


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def _route_allgather(route_buf: EventBatch, D: int) -> EventBatch:
    if D == 1:
        return route_buf
    g = jax.tree.map(lambda x: jax.lax.all_gather(x, AXIS), route_buf)
    return EventBatch(*(x.reshape(-1) for x in g))


def _select_send_global(prod: EventBatch, eligible, cfg: EngineConfig):
    """allgather routing: the first route_cap eligible events are sent."""
    rank = jnp.cumsum(eligible.astype(jnp.int32)) - 1
    send = eligible & (rank < cfg.route_cap)
    ovf = jnp.sum((eligible & ~send).astype(jnp.int32))
    buf = truncate(compact_mask(prod, send), cfg.route_cap)
    return buf, send, ovf


def _select_send_a2a(prod: EventBatch, eligible, placement: Placement,
                     cfg: EngineConfig):
    """a2a routing: per-destination-device sub-buffers of pair_cap events."""
    D = placement.n_devices
    pair_cap = cfg.route_cap // D
    owner = placement.owner(prod.dst)
    key = jnp.where(eligible, owner, D)
    order = jnp.argsort(key, stable=True)
    ks = key[order]
    idx = jnp.arange(ks.shape[0], dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    start_idx = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    rank = idx - start_idx
    ok = (ks < D) & (rank < pair_cap)
    ovf = jnp.sum(((ks < D) & ~ok).astype(jnp.int32))

    slot = jnp.where(ok, ks * pair_cap + rank, D * pair_cap)

    def put(field, fill, dtype):
        out = jnp.full((D * pair_cap,), fill, dtype)
        return out.at[slot].set(field[order], mode="drop")

    valid = jnp.zeros((D * pair_cap,), bool).at[slot].set(True, mode="drop")
    buf = EventBatch(
        dst=put(prod.dst, 0, jnp.int32),
        ts=put(prod.ts, jnp.inf, jnp.float32),
        seed=put(prod.seed, 0, jnp.uint32),
        payload=put(prod.payload, 0.0, jnp.float32),
        valid=valid,
    )
    # sent mask back in original event order
    send = jnp.zeros_like(eligible).at[order].set(ok)
    return buf, send, ovf


def _route_a2a(buf: EventBatch, D: int, pair_cap: int) -> EventBatch:
    shaped = jax.tree.map(lambda x: x.reshape(D, pair_cap), buf)
    recv = jax.tree.map(
        lambda x: jax.lax.all_to_all(x, AXIS, split_axis=0, concat_axis=0,
                                     tiled=True), shaped)
    return EventBatch(*(x.reshape(-1) for x in recv))


# ---------------------------------------------------------------------------
# the per-device epoch step
# ---------------------------------------------------------------------------

def make_step(model: SimModel, cfg: EngineConfig, placement: Placement
              ) -> Callable[[EngineState], EngineState]:
    D = placement.n_devices
    n_local = placement.n_local_max
    N, C = cfg.n_buckets, cfg.bucket_cap

    if cfg.scheduler == "ltf":
        process = _process_ltf
    elif cfg.batch_impl == "model":
        if not hasattr(model, "process_batch"):
            raise ValueError("batch_impl='model' needs model.process_batch")

        def process(model_, obj, ts_s, seed_s, pay_s, cnt_b, lookahead):
            return model_.process_batch(obj, ts_s, seed_s, pay_s, cnt_b,
                                        lookahead)
    else:
        process = _process_batch

    def step(state: EngineState) -> EngineState:
        dev = jax.lax.axis_index(AXIS)
        cur = state.epoch[0]
        cal, ts_s, seed_s, pay_s, cnt_b = extract_sorted(state.cal, cur)
        obj = state.obj
        stolen = jnp.int32(0)

        if cfg.steal and D > 1:
            (obj, out_flat, lv, stolen, proc_count) = _step_with_steal(
                model, cfg, placement, dev, obj, ts_s, seed_s, pay_s, cnt_b)
        else:
            obj, out_flat, lv = process(model, obj, ts_s, seed_s, pay_s, cnt_b,
                                        cfg.lookahead)
            proc_count = jnp.sum(cnt_b)

        # --- producer-side triage: fresh events + fallback entries ---------
        prod = concat_batches(out_flat, state.fb.events)
        epochs = _epoch_of(prod.ts, cfg.epoch_len)
        eligible = prod.valid & (epochs >= cur + 1) & (epochs <= cur + N)
        late_prod = prod.valid & (epochs <= cur)
        n_late_prod = jnp.sum(late_prod.astype(jnp.int32))

        if cfg.route == "a2a" and D > 1:
            route_buf, send, route_ovf = _select_send_a2a(
                prod, eligible, placement, cfg)
        else:
            route_buf, send, route_ovf = _select_send_global(prod, eligible, cfg)

        keep = prod.valid & ~send & ~late_prod
        kept = compact_mask(prod, keep)
        fb = Fallback(truncate(kept, cfg.fallback_cap))
        fb_ovf = jnp.sum(kept.valid[cfg.fallback_cap:].astype(jnp.int32))

        # --- exchange -------------------------------------------------------
        if D == 1:
            routed = route_buf
        elif cfg.route == "a2a":
            routed = _route_a2a(route_buf, D, cfg.route_cap // D)
        else:
            routed = _route_allgather(route_buf, D)

        # --- delivery -------------------------------------------------------
        cal, fb, cal_ovf, fb_ovf2, late2 = _deliver(
            cal, fb, routed, cur, dev, placement, cfg, init=False)

        st = state.stats
        stats = Stats(
            processed=st.processed + proc_count,
            cal_overflow=st.cal_overflow + cal_ovf,
            fb_overflow=st.fb_overflow + fb_ovf + fb_ovf2,
            route_overflow=st.route_overflow + route_ovf,
            late_events=st.late_events + n_late_prod + late2,
            lookahead_violations=st.lookahead_violations + lv,
            stolen=st.stolen + stolen,
        )
        return EngineState(cal, fb, obj, state.epoch + 1, stats)

    return step


def _step_with_steal(model, cfg, placement, dev, obj, ts_s, seed_s, pay_s,
                     cnt_b):
    """Stealing-enabled processing: publish loans, claim, process, return."""
    D = placement.n_devices
    C = cfg.bucket_cap
    boundaries = jnp.asarray(placement.boundaries, jnp.int32)

    load = jnp.sum(cnt_b)
    loads = jax.lax.all_gather(load, AXIS)                     # [D]
    total = jnp.sum(loads)
    target = (total + D - 1) // D

    top_idx, top_w, loan_valid = steal_mod.select_loans(
        cnt_b, load, target, cfg.steal_cap)

    pub = {
        "state": steal_mod.gather_rows(obj, top_idx),
        "ts": ts_s[top_idx], "seed": seed_s[top_idx], "pay": pay_s[top_idx],
        "cnt": top_w, "gid": top_idx + boundaries[dev], "valid": loan_valid,
    }
    pub_g = jax.tree.map(lambda x: jax.lax.all_gather(x, AXIS), pub)  # [D, sc,…]

    plan = steal_mod.plan_loans(loads, pub_g["cnt"], pub_g["valid"],
                                cfg.claim_cap)

    # donor side: claimed loans are processed remotely — zero them here.
    own_claimed = plan.claimed.reshape(D, cfg.steal_cap)[dev]
    cnt_b = cnt_b.at[top_idx].set(
        jnp.where(own_claimed & loan_valid, 0, cnt_b[top_idx]))

    # receiver side: compact my claims into claim_cap rows.
    claim_mask = plan.claimed & (plan.assignee == dev)         # [D*sc]
    corder = jnp.argsort(~claim_mask, stable=True)[:cfg.claim_cap]
    cvalid = claim_mask[corder]
    flat = lambda l: l.reshape((D * cfg.steal_cap,) + l.shape[2:])
    cl_state = jax.tree.map(lambda l: flat(l)[corder], pub_g["state"])
    cl_ts = flat(pub_g["ts"])[corder]
    cl_seed = flat(pub_g["seed"])[corder]
    cl_pay = flat(pub_g["pay"])[corder]
    cl_cnt = jnp.where(cvalid, flat(pub_g["cnt"])[corder], 0)
    cl_gid = flat(pub_g["gid"])[corder]

    n_local = cnt_b.shape[0]
    obj_aug = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                           obj, cl_state)
    ts_aug = jnp.concatenate([ts_s, cl_ts], axis=0)
    seed_aug = jnp.concatenate([seed_s, cl_seed], axis=0)
    pay_aug = jnp.concatenate([pay_s, cl_pay], axis=0)
    cnt_aug = jnp.concatenate([cnt_b, cl_cnt], axis=0)

    obj_aug, out_flat, lv = _process_batch(model, obj_aug, ts_aug, seed_aug,
                                           pay_aug, cnt_aug, cfg.lookahead)
    obj = jax.tree.map(lambda l: l[:n_local], obj_aug)
    ret_state = jax.tree.map(lambda l: l[n_local:], obj_aug)

    ret = {"state": ret_state, "gid": cl_gid, "valid": cvalid}
    ret_g = jax.tree.map(lambda x: jax.lax.all_gather(x, AXIS), ret)
    rgid = ret_g["gid"].reshape(-1)
    rvalid = ret_g["valid"].reshape(-1)
    rmine = rvalid & (placement.owner(rgid) == dev)
    lidx = jnp.clip(rgid - boundaries[dev], 0, n_local - 1)
    rstate = jax.tree.map(lambda l: l.reshape((-1,) + l.shape[2:]),
                          ret_g["state"])
    obj = steal_mod.scatter_rows(obj, lidx, rstate, rmine)

    proc_count = jnp.sum(cnt_b) + jnp.sum(cl_cnt)
    return obj, out_flat, lv, jnp.sum(cvalid.astype(jnp.int32)), proc_count


# ---------------------------------------------------------------------------
# the engine wrapper
# ---------------------------------------------------------------------------

class ParsirEngine:
    """Build, initialize and run a PARSIR simulation on a device mesh."""

    def __init__(self, model: SimModel, cfg: EngineConfig,
                 mesh: Mesh | None = None):
        if mesh is None:
            mesh = Mesh(np.array(jax.devices()[:1]), (AXIS,))
        self.model, self.cfg, self.mesh = model, cfg, mesh
        D = int(np.prod(mesh.devices.shape))
        if model.n_objects % D:
            raise ValueError(f"n_objects={model.n_objects} not divisible by "
                             f"mesh size {D}")
        if cfg.route == "a2a" and cfg.route_cap % D:
            raise ValueError("route_cap must be divisible by mesh size for a2a")
        self.placement = equal_placement(model.n_objects, D)
        self.D = D

        self._step = make_step(model, cfg, self.placement)
        spec = P(AXIS)
        self._sharding = NamedSharding(mesh, spec)
        self._step_sm = jax.jit(_shard_map(self._step, mesh, (spec,), spec),
                                donate_argnums=0)
        self._run_cache: dict[int, Any] = {}

        def ingest(state: EngineState, batch: EventBatch) -> EngineState:
            dev = jax.lax.axis_index(AXIS)
            cur = state.epoch[0]
            cal, fb, cal_ovf, fb_ovf, late = _deliver(
                state.cal, state.fb, batch, cur, dev, self.placement, cfg,
                init=True)
            st = state.stats
            stats = st._replace(cal_overflow=st.cal_overflow + cal_ovf,
                                fb_overflow=st.fb_overflow + fb_ovf,
                                late_events=st.late_events + late)
            return EngineState(cal, fb, state.obj, state.epoch, stats)

        self._ingest = jax.jit(_shard_map(ingest, mesh, (spec, P()), spec))

    # -- lifecycle -------------------------------------------------------------

    def init(self) -> EngineState:
        O, D = self.model.n_objects, self.D
        cfg = self.cfg
        obj_np = self.model.init_object_state(np.arange(O))
        obj = jax.tree.map(
            lambda l: jax.device_put(l, self._sharding), obj_np)
        cal = make_calendar(O, cfg.n_buckets, cfg.bucket_cap)
        cal = jax.tree.map(lambda l: jax.device_put(l, self._sharding), cal,
                           is_leaf=lambda x: isinstance(x, jax.Array))
        fb = make_fallback(D * cfg.fallback_cap)
        fb = jax.tree.map(lambda l: jax.device_put(l, self._sharding), fb,
                          is_leaf=lambda x: isinstance(x, jax.Array))
        epoch = jax.device_put(jnp.zeros((D,), jnp.int32), self._sharding)
        stats = jax.tree.map(
            lambda l: jax.device_put(jnp.tile(l, D), self._sharding),
            zero_stats())
        state = EngineState(cal, fb, obj, epoch, stats)

        init_ev = self.model.initial_events()
        batch = EventBatch(
            dst=jnp.asarray(init_ev["dst"], jnp.int32),
            ts=jnp.asarray(init_ev["ts"], jnp.float32),
            seed=jnp.asarray(init_ev["seed"], jnp.uint32),
            payload=jnp.asarray(init_ev["payload"], jnp.float32),
            valid=jnp.ones((len(init_ev["dst"]),), bool),
        )
        return self._ingest(state, batch)

    def step(self, state: EngineState) -> EngineState:
        return self._step_sm(state)

    def run(self, state: EngineState, n_epochs: int) -> EngineState:
        if n_epochs not in self._run_cache:
            def run_n(s):
                def body(s, _):
                    return self._step(s), ()
                s, _ = jax.lax.scan(body, s, None, length=n_epochs)
                return s
            spec = P(AXIS)
            self._run_cache[n_epochs] = jax.jit(
                _shard_map(run_n, self.mesh, (spec,), spec), donate_argnums=0)
        return self._run_cache[n_epochs](state)

    # -- inspection -------------------------------------------------------------

    def totals(self, state: EngineState) -> dict[str, int]:
        st = jax.tree.map(lambda l: int(np.sum(np.asarray(l))), state.stats)
        return st._asdict()

    def in_flight(self, state: EngineState) -> int:
        cal = int(np.sum(np.asarray(state.cal.cnt)))
        fb = int(np.sum(np.asarray(state.fb.events.valid)))
        return cal + fb
