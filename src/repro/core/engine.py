"""The PARSIR epoch-synchronous conservative engine (paper §II), in JAX.

One SPMD program instance per mesh device plays the role of a PARSIR worker
thread pinned to a CPU; a device's HBM plays the NUMA node.  An engine step
processes exactly one epoch through the stage pipeline of
:mod:`repro.core.pipeline`:

  1. **extract** — drain the calendar bucket of the current epoch for all local
     objects, sorted per object by (ts, seed) (lock-free: exclusive ownership,
     see calendar.py);
  2. **steal (optional)** — epoch-granular loans of hot objects from overloaded
     to underloaded devices (``StealPolicy``), decided from the globally
     gathered load vector (possible because the lookahead closes the epoch's
     workload);
  3. **process** — the per-object *batch* execution at the heart of the paper
     (``Scheduler``): round r applies the r-th in-order event of every object
     in parallel (vmap), so each object's state stays register/VMEM-hot across
     its whole batch while objects are processed in parallel;
  3b. **rebalance (optional)** — with ``placement="adaptive"``, every
     ``rebalance_every`` epochs the placement boundaries are recomputed from
     measured per-object load and moved objects (state + calendar rows)
     migrate to their new owners (``RebalancePolicy``, paper §II-C);
  4. **route** — emitted events plus drained fallback entries are exchanged
     (``Router``: `allgather` mirrors the shared-memory "any thread enqueues
     anywhere" semantics; `a2a` is the optimized pairwise exchange);
  5. **deliver** — owners insert routed events into calendar buckets (conflict-
     free scatter) or park beyond-horizon events in the fallback buffer;
  6. **barrier** — implicit in the collectives; epoch advances everywhere.

Object → device placement is contiguous-by-id (the paper's NUMA knapsack):
``EngineConfig.placement`` selects ``equal`` ranges, ``weighted`` ranges
balancing the model's :meth:`~repro.core.api.SimModel.object_weights` hint,
or ``adaptive`` runtime rebalancing.  Because placements may be uneven while
SPMD sharding must be even, every device materializes ``n_local_max`` object
rows (the *pad*); rows beyond a device's live range are inert — zero calendar
counts, never receiving events.  With the default equal placement on a
divisible object count the pad is exact and the layout is identical to the
classic one.  The live boundaries vector rides in ``EngineState`` so the
rebalance stage can move it without retracing.

Event flow is variable-arity end to end: each processed event emits
0..``model.max_out`` successors (``EmittedEvents`` rows with ``valid`` masks
honored at every stage), so open networks — sources fanning out, sinks
absorbing — run through the same pipeline as the classic one-in/one-out
workloads.

All capacities are static; every overflow/causality condition is *counted* in
``Stats`` and surfaced — a conservative engine must never silently drop or
reorder, so drivers (and tests) assert these counters stay zero.

The host loop itself is on-device: :meth:`ParsirEngine.run` advances a fixed
epoch count as one compiled chunked program (the count is a traced operand —
no per-length retrace), and :meth:`ParsirEngine.run_until_drained` fuses the
whole drain-to-empty simulation into a single ``lax.while_loop`` dispatch
with donated buffers (see docs/architecture.md, "The fused on-device drain
loop").

This module is the user-facing wrapper (:class:`ParsirEngine`: mesh setup,
sharding, lifecycle) and re-exports the pipeline's stable names
(``EngineConfig``, ``EngineState``, ``Stats``, ``AXIS``, ``make_step``) so
historical ``repro.core.engine`` imports keep working.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .api import SimModel
from .calendar import bucket_occupancy, make_calendar, make_fallback
from .events import EventBatch
from .pipeline import (AXIS, EngineConfig, EngineState, Stats, deliver,
                       make_spec_step, make_step, zero_stats)
from .pipeline.base import stats_dtype
from .placement import Placement, equal_placement, weighted_placement

__all__ = ["AXIS", "REP_AXIS", "EngineConfig", "EngineState", "ParsirEngine",
           "Stats", "make_spec_step", "make_step", "zero_stats"]

#: mesh axis name for replication-sharded campaigns (``rep_shards``): the
#: device grid is ``(REP_AXIS=W, AXIS=1)``, so the step's collectives over
#: ``AXIS`` are single-member no-ops and each replication stays local.
REP_AXIS = "replications"


def _shard_map(f, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def build_placement(model: SimModel, cfg: EngineConfig, D: int) -> Placement:
    """Resolve ``cfg.placement`` into the engine's initial Placement.

    ``weighted``/``adaptive`` consult the model's optional ``object_weights``
    hint (falling back to the equal split when the model declares none);
    ``adaptive`` additionally widens the per-device row pad by
    ``placement_slack`` so the boundaries have static headroom to skew.
    """
    O = model.n_objects
    if cfg.placement == "equal":
        return equal_placement(O, D)
    w = model.object_weights()
    pl = equal_placement(O, D) if w is None else weighted_placement(w, D)
    if cfg.placement == "adaptive":
        pad = min(O, int(math.ceil(O / D * cfg.placement_slack)))
        pl = pl.padded(max(pl.n_local_max, pad))
    return pl


class ParsirEngine:
    """Build, initialize and run a PARSIR simulation on a device mesh."""

    def __init__(self, model: SimModel, cfg: EngineConfig,
                 mesh: Mesh | None = None, rep_shards: int | None = None):
        """``mesh`` shards the *object* axis (the classic PARSIR layout:
        D workers share one simulation).  ``rep_shards=W`` instead shards the
        *replication* axis of :meth:`init_replicated` stacks across W devices
        — each replication runs whole (collective-free) on its device, which
        is the throughput layout for campaigns whose single replication fits
        one device.  ``rep_shards`` requires the engine's own mesh to be
        single-device and ``len(seeds) % W == 0``."""
        if mesh is None:
            mesh = Mesh(np.array(jax.devices()[:1]), (AXIS,))
        self.model, self.cfg, self.mesh = model, cfg, mesh
        D = int(np.prod(mesh.devices.shape))
        cfg.validate(D)
        self.placement = build_placement(model, cfg, D)
        self.D = D

        self._step = make_step(model, cfg, self.placement)
        #: the bounded-optimism (Time Warp lite) step — built only when the
        #: config asks for it.  With opt_window == 0 nothing speculative is
        #: even constructed and every compiled path below is byte-identical
        #: to a pre-speculation build (no shadow copies, no second exchange).
        self._spec_step = (make_spec_step(model, cfg, self.placement)
                           if cfg.opt_window > 0 else None)
        spec = P(AXIS)
        rep_spec = P(None, AXIS)   # stacked leaves: [R, ...] sharded on dim 1
        self._sharding = NamedSharding(mesh, spec)
        self._rep_sharding = NamedSharding(mesh, rep_spec)
        self._step_sm = jax.jit(_shard_map(self._step, mesh, (spec,), spec),
                                donate_argnums=0)
        #: host-side XLA program launches (init ingest, step, run chunks,
        #: fused drains) — the honest dispatches-per-simulation number the
        #: benchmarks report.
        self.dispatches = 0
        #: lazily compiled drain programs per live window width, used by the
        #: adaptive-W controller (cfg.opt_adaptive): EngineState layout is
        #: W-independent, so the same state flows through any variant.
        self._drain_variants: dict[int, object] = {}

        def in_flight_device(s: EngineState) -> jax.Array:
            # the drain predicate's operand: global events still parked in
            # calendars + fallback lists (device-local sum, psum over AXIS).
            local = (jnp.sum(s.cal.cnt)
                     + jnp.sum(s.fb.events.valid.astype(jnp.int32)))
            return jax.lax.psum(local, AXIS)

        def run_n(state: EngineState, n: jax.Array) -> EngineState:
            # n is a *traced* operand: one compiled program serves every
            # epoch count (the old per-n_epochs scan retraced per length).
            return jax.lax.fori_loop(0, n, lambda i, s: self._step(s), state)

        if self._spec_step is not None:
            def run_n(state: EngineState, n: jax.Array) -> EngineState:
                # A speculative step advances a *variable* epoch count
                # (W_eff + 1 on commit, 1 on abort), so the fixed-trip
                # fori_loop becomes a while_loop on the replicated epoch
                # counter.  The bound rides into the step, which clamps its
                # last window to land on exactly epoch start + n — run(n)
                # stays horizon-exact vs the oracle.
                bound = state.epoch[0] + n
                return jax.lax.while_loop(
                    lambda s: s.epoch[0] < bound,
                    lambda s: self._spec_step(s, bound), state)

        self._run_sm = jax.jit(
            _shard_map(run_n, mesh, (spec, P()), spec), donate_argnums=0)

        def drain(state: EngineState, max_epochs: jax.Array) -> EngineState:
            # Fused on-device drain loop: a single lax.while_loop whose body
            # is the epoch step.  The carry is (state, epochs_run, in_flight);
            # in_flight is computed (with its psum) at the END of the body so
            # the cond stays collective-free — every device computes the same
            # replicated predicate and the loop exits in lockstep.
            def cond(carry):
                s, n, pending = carry
                return (pending > 0) & (n < max_epochs)

            def body(carry):
                s, n, _ = carry
                s = self._step(s)
                return s, n + jnp.int32(1), in_flight_device(s)

            s, _, _ = jax.lax.while_loop(
                cond, body, (state, jnp.int32(0), in_flight_device(state)))
            return s

        if self._spec_step is not None:
            def drain(state: EngineState, max_epochs: jax.Array) -> EngineState:
                # Speculative fused drain: one while iteration is one
                # committed-or-aborted window (epochs-to-drain, the number
                # the it6 bench reports, is spec_commits + rollbacks), so
                # the cap moves off the iteration count onto the replicated
                # epoch counter — a window advances up to opt_window + 1
                # epochs at once.  The shadow copies live entirely inside
                # the step body; the loop carry is unchanged.
                bound = state.epoch[0] + max_epochs

                def cond(carry):
                    s, pending = carry
                    return (pending > 0) & (s.epoch[0] < bound)

                def body(carry):
                    s, _ = carry
                    s = self._spec_step(s, bound)
                    return s, in_flight_device(s)

                s, _ = jax.lax.while_loop(
                    cond, body, (state, in_flight_device(state)))
                return s

        self._drain_sm = jax.jit(
            _shard_map(drain, mesh, (spec, P()), spec), donate_argnums=0)
        if self._spec_step is not None:
            # the full-width drain doubles as the adaptive controller's
            # starting variant — no duplicate compile for w == opt_window.
            self._drain_variants[cfg.opt_window] = self._drain_sm

        def drain_replicated(state: EngineState,
                             max_epochs: jax.Array) -> EngineState:
            # The replication-vmapped fused drain: R independent simulations
            # advance inside ONE lax.while_loop dispatch.  Every leaf of the
            # carry is the [R, ...]-stacked per-device state; the body vmaps
            # the epoch step over the replication axis (the collectives
            # inside the step batch over R via their vmap rules, so one
            # psum/all_gather/all_to_all serves all replications at once).
            #
            # Exit + freezing: the predicate is ANY replication still having
            # in-flight events; a replication whose own pending count hit
            # zero is *frozen* — the body computes its step but jnp.where
            # keeps the old leaves — so its epoch counter and Stats stop at
            # exactly its own drain epoch and its final state is leaf-exact
            # vs an independent run_until_drained of that seed.  As in the
            # scalar drain, pending is computed at the body END so the cond
            # stays collective-free.
            vstep = jax.vmap(self._step)
            freeze = self._freeze_replications

            def pending_of(s: EngineState) -> jax.Array:
                per_rep = jax.vmap(
                    lambda t: jnp.sum(t.cal.cnt)
                    + jnp.sum(t.fb.events.valid.astype(jnp.int32)))(s)
                return jax.lax.psum(per_rep, AXIS)          # i32 [R]

            def cond(carry):
                s, n, pending = carry
                return jnp.any(pending > 0) & (n < max_epochs)

            def body(carry):
                s, n, pending = carry
                active = pending > 0                        # bool [R]
                s = freeze(active, vstep(s), s)
                return s, n + jnp.int32(1), pending_of(s)

            s, _, _ = jax.lax.while_loop(
                cond, body, (state, jnp.int32(0), pending_of(state)))
            return s

        if self._spec_step is not None:
            def drain_replicated(state: EngineState,
                                 max_epochs: jax.Array) -> EngineState:
                # Replications commit/abort independently, so their epoch
                # counters diverge — each gets its own bound and freezes
                # when it drains or reaches it.  The freeze contract holds
                # unchanged: a drained replication's speculative step is a
                # bit-exact no-op (empty buckets speculate nothing, V == 0,
                # commit delivers nothing) and its advancing leaves (epoch,
                # Stats incl. spec_commits) take the mask.
                bounds_r = state.epoch[:, 0] + max_epochs       # i32 [R]
                vstep = jax.vmap(self._spec_step)
                freeze = self._freeze_replications

                def pending_of(s: EngineState) -> jax.Array:
                    per_rep = jax.vmap(
                        lambda t: jnp.sum(t.cal.cnt)
                        + jnp.sum(t.fb.events.valid.astype(jnp.int32)))(s)
                    return jax.lax.psum(per_rep, AXIS)          # i32 [R]

                def cond(carry):
                    s, pending = carry
                    return jnp.any((pending > 0)
                                   & (s.epoch[:, 0] < bounds_r))

                def body(carry):
                    s, pending = carry
                    active = (pending > 0) & (s.epoch[:, 0] < bounds_r)
                    s = freeze(active, vstep(s, bounds_r), s)
                    return s, pending_of(s)

                s, _ = jax.lax.while_loop(
                    cond, body, (state, pending_of(state)))
                return s

        self._drain_rep_sm = jax.jit(
            _shard_map(drain_replicated, mesh, (rep_spec, P()), rep_spec),
            donate_argnums=0)

        def ingest(state: EngineState, batch: EventBatch) -> EngineState:
            dev = jax.lax.axis_index(AXIS)
            cur = state.epoch[0]
            pl = self.placement.with_boundaries(state.bounds[0])
            cal, fb, cal_ovf, fb_ovf, late, oob = deliver(
                state.cal, state.fb, batch, cur, dev, pl, cfg, init=True,
                replicated=True)
            st = state.stats
            stats = st._replace(cal_overflow=st.cal_overflow + cal_ovf,
                                fb_overflow=st.fb_overflow + fb_ovf,
                                late_events=st.late_events + late,
                                oob_events=st.oob_events + oob)
            return state._replace(cal=cal, fb=fb, stats=stats)

        self._ingest = jax.jit(_shard_map(ingest, mesh, (spec, P()), spec))
        self._ingest_rep = jax.jit(
            _shard_map(jax.vmap(ingest), mesh, (rep_spec, P()), rep_spec))

        self.rep_shards = None if rep_shards is None else int(rep_shards)
        if self.rep_shards is not None:
            W = self.rep_shards
            if D != 1:
                raise ValueError(
                    f"rep_shards requires a single-device engine mesh (got "
                    f"D={D}): each replication runs whole on one device")
            devs = jax.devices()
            if W < 1 or len(devs) < W:
                raise ValueError(
                    f"rep_shards={W} needs {W} devices, have {len(devs)}")
            # 2D device grid (REP_AXIS=W, AXIS=1): inside a shard the step's
            # AXIS collectives act over a single member (identity), so every
            # replication advances collective-free on its own device and the
            # drain needs no cross-device traffic at all (each device's
            # while_loop exits at its own local drain epoch).
            mesh2 = Mesh(np.array(devs[:W]).reshape(W, 1), (REP_AXIS, AXIS))
            rspec = P(REP_AXIS)   # stacked leaves sharded on the leading R
            self._rep_mesh = mesh2
            self._rep_sharding = NamedSharding(mesh2, rspec)

            def drain_rep_sharded(state: EngineState,
                                  max_epochs: jax.Array) -> EngineState:
                # Same freeze contract as drain_replicated, but pending is
                # the LOCAL [R/W] slice and — because the whole body is
                # collective-free across devices (the AXIS collectives are
                # single-member) — the cond can be local too: each device's
                # while_loop exits as soon as ITS replications drain, with
                # no cross-device sync at any point in the drain.
                vstep = jax.vmap(self._step)
                freeze = self._freeze_replications

                def pending_of(s: EngineState) -> jax.Array:
                    per_rep = jax.vmap(
                        lambda t: jnp.sum(t.cal.cnt)
                        + jnp.sum(t.fb.events.valid.astype(jnp.int32)))(s)
                    return jax.lax.psum(per_rep, AXIS)      # i32 [R/W]

                def cond(carry):
                    s, n, p_loc = carry
                    return jnp.any(p_loc > 0) & (n < max_epochs)

                def body(carry):
                    s, n, p_loc = carry
                    active = p_loc > 0                      # bool [R/W]
                    s = freeze(active, vstep(s), s)
                    return s, n + jnp.int32(1), pending_of(s)

                s, _, _ = jax.lax.while_loop(
                    cond, body, (state, jnp.int32(0), pending_of(state)))
                return s

            if self._spec_step is not None:
                def drain_rep_sharded(state: EngineState,
                                      max_epochs: jax.Array) -> EngineState:
                    # Per-rep epoch bounds as in the vmapped drain; the cond
                    # stays local (the AXIS collectives inside the spec step
                    # — the verdict all_gather included — are single-member
                    # no-ops, so the [D, 2] verdict table collapses to this
                    # replication's own [m_local, v_local] and each device's
                    # loop still exits at its own local drain epoch).
                    bounds_r = state.epoch[:, 0] + max_epochs   # i32 [R/W]
                    vstep = jax.vmap(self._spec_step)
                    freeze = self._freeze_replications

                    def pending_of(s: EngineState) -> jax.Array:
                        per_rep = jax.vmap(
                            lambda t: jnp.sum(t.cal.cnt)
                            + jnp.sum(t.fb.events.valid.astype(jnp.int32)))(s)
                        return jax.lax.psum(per_rep, AXIS)      # i32 [R/W]

                    def cond(carry):
                        s, p_loc = carry
                        return jnp.any((p_loc > 0)
                                       & (s.epoch[:, 0] < bounds_r))

                    def body(carry):
                        s, p_loc = carry
                        active = (p_loc > 0) & (s.epoch[:, 0] < bounds_r)
                        s = freeze(active, vstep(s, bounds_r), s)
                        return s, pending_of(s)

                    s, _ = jax.lax.while_loop(
                        cond, body, (state, pending_of(state)))
                    return s

            self._drain_rep_sm = jax.jit(
                _shard_map(drain_rep_sharded, mesh2, (rspec, P()), rspec),
                donate_argnums=0)
            self._ingest_rep = jax.jit(
                _shard_map(jax.vmap(ingest), mesh2, (rspec, rspec), rspec))

    def _freeze_replications(self, active, stepped: EngineState,
                             old: EngineState) -> EngineState:
        """Per-replication freeze for the stacked drains: keep ``old`` leaves
        wherever ``active`` (the PRE-step pending mask, bool [R]) is False,
        so a drained replication stops at exactly its own drain epoch.

        The select is *light* where the drained-state fixpoint already
        guarantees bit-equality: an empty calendar extracts, processes,
        routes and delivers nothing, so the per-slot calendar buffers — by
        far the largest state in the system — leave the step bit-identical
        for frozen replications and ride through unmasked.  Selecting them
        too forces a full-array copy every epoch, which measured *slower*
        than the sequential host loop at campaign scale.  Only the leaves
        the step advances unconditionally (epoch counter, decaying load,
        Stats) plus the cheap small buffers take the mask.  Adaptive
        placement is the exception: a post-drain rebalance may still
        migrate rows, so it keeps the full-tree select.
        """
        def sel(new, olds):
            return jnp.where(
                active.reshape((-1,) + (1,) * (new.ndim - 1)), new, olds)
        if self.cfg.placement == "adaptive":
            return jax.tree.map(sel, stepped, old)
        return stepped._replace(
            cal=stepped.cal._replace(cnt=sel(stepped.cal.cnt, old.cal.cnt)),
            fb=jax.tree.map(sel, stepped.fb, old.fb),
            obj=jax.tree.map(sel, stepped.obj, old.obj),
            epoch=sel(stepped.epoch, old.epoch),
            stats=jax.tree.map(sel, stepped.stats, old.stats),
            bounds=sel(stepped.bounds, old.bounds),
            load=sel(stepped.load, old.load))

    # -- lifecycle -------------------------------------------------------------

    def _fresh_state(self, R: int | None) -> EngineState:
        """The zeroed pre-ingest EngineState; ``R`` stacks every leaf with a
        leading replication axis (sharded ``P(None, AXIS)``), ``None`` builds
        the classic single-simulation layout."""
        D, M = self.D, self.placement.n_local_max
        cfg = self.cfg
        sharding = self._sharding if R is None else self._rep_sharding
        rep = ((lambda l: l) if R is None
               else (lambda l: jnp.broadcast_to(l[None], (R,) + l.shape)))
        put = lambda l: jax.device_put(rep(jnp.asarray(l)), sharding)
        obj = jax.tree.map(
            put, self.model.init_object_state(self.placement.padded_gids()))
        cal = jax.tree.map(put,
                           make_calendar(D * M, cfg.n_buckets, cfg.bucket_cap))
        fb = jax.tree.map(put, make_fallback(D * cfg.fallback_cap))
        epoch = put(jnp.zeros((D,), jnp.int32))
        stats = jax.tree.map(lambda l: put(jnp.tile(l, D)), zero_stats())
        b = jnp.asarray(np.asarray(self.placement.boundaries, np.int32))
        bounds = put(jnp.tile(b[None, :], (D, 1)))
        load = put(jnp.zeros((D * M,), jnp.int32))
        return EngineState(cal, fb, obj, epoch, stats, bounds, load)

    def _initial_batch(self, seed: int | None) -> EventBatch:
        init_ev = (self.model.initial_events() if seed is None
                   else self.model.initial_events(seed))
        return EventBatch(
            dst=jnp.asarray(init_ev["dst"], jnp.int32),
            ts=jnp.asarray(init_ev["ts"], jnp.float32),
            seed=jnp.asarray(init_ev["seed"], jnp.uint32),
            payload=jnp.asarray(init_ev["payload"], jnp.float32),
            valid=jnp.ones((len(init_ev["dst"]),), bool),
        )

    def init(self, seed: int | None = None) -> EngineState:
        """Build the initial state and ingest the bootstrap events.

        ``seed`` selects the replication stream (forwarded to the model's
        ``initial_events``); ``None`` keeps the model's own default."""
        state = self._fresh_state(None)
        self.dispatches += 1
        return self._ingest(state, self._initial_batch(seed))

    def init_replicated(self, seeds) -> EngineState:
        """Build an R-replication stacked state, one bootstrap stream per
        seed.  Every leaf leads with the replication axis ``R = len(seeds)``
        (initial object state is identical across replications — trajectories
        diverge through the seed-salted bootstrap events alone); run it with
        :meth:`run_replicated_drained`."""
        seeds = [int(s) for s in seeds]
        if not seeds:
            raise ValueError("init_replicated needs at least one seed")
        if self.rep_shards and len(seeds) % self.rep_shards:
            raise ValueError(
                f"rep_shards={self.rep_shards} needs len(seeds) divisible by"
                f" it (got {len(seeds)})")
        state = self._fresh_state(len(seeds))
        batches = [self._initial_batch(s) for s in seeds]
        batch = EventBatch(*(jnp.stack(ls) for ls in zip(*batches)))
        self.dispatches += 1
        return self._ingest_rep(state, batch)

    def check_stats_bound(self, n_epochs: int) -> None:
        """Fail fast if ``n_epochs`` epochs could overflow a Stats counter.

        The in-carry ledger accumulates in :func:`stats_dtype` — int32 unless
        ``JAX_ENABLE_X64=1`` widens it to int64 — and int32 overflow would
        wrap *silently* inside the fused loop.  The worst-case per-device
        per-epoch increment of any counter is bounded by the largest static
        buffer a stage can fill: the epoch bucket (``n_local_max *
        bucket_cap``, plus claimed loans under stealing), the route buffer,
        or the fallback list.  Every run entry point checks this bound
        before dispatching.
        """
        cap = int(jnp.iinfo(stats_dtype()).max)
        per_epoch = self.placement.n_local_max * self.cfg.bucket_cap
        if self.cfg.steal:
            per_epoch += self.cfg.claim_cap * self.cfg.bucket_cap
        per_epoch = max(per_epoch, self.cfg.route_cap, self.cfg.fallback_cap)
        if int(n_epochs) * per_epoch > cap:
            raise ValueError(
                f"{n_epochs} epochs could overflow the {stats_dtype().__name__}"
                f" Stats counters (worst-case {per_epoch} events/epoch/device,"
                f" bound {int(n_epochs) * per_epoch:,} > {cap:,}); set"
                f" JAX_ENABLE_X64=1 to widen the ledger to int64, or split"
                f" the horizon")

    def step(self, state: EngineState) -> EngineState:
        """Advance exactly one epoch (always the conservative step — the
        single-epoch contract leaves no room to speculate; ``opt_window``
        engages inside :meth:`run` and the fused drains)."""
        self.dispatches += 1
        return self._step_sm(state)

    def run(self, state: EngineState, n_epochs: int) -> EngineState:
        """Advance exactly ``n_epochs`` epochs in one XLA dispatch.

        The epoch count is a traced operand of one compiled chunked program
        (an on-device ``fori_loop``), so calling with a new ``n_epochs``
        never retraces — the historical per-length ``scan`` cache is retired.
        ``state`` is donated: rebind the result, the input handle dies.
        """
        self.check_stats_bound(n_epochs)
        self.dispatches += 1
        return self._run_sm(state, jnp.int32(n_epochs))

    def run_until_drained(self, state: EngineState,
                          max_epochs: int) -> EngineState:
        """Run to empty — an entire simulation as ONE XLA dispatch.

        A single ``lax.while_loop`` whose body is the epoch step and whose
        carry holds the drain predicate: the loop exits when no event is
        parked anywhere (``sum(cal.cnt) + sum(fb.valid) == 0``, the same
        quantity :meth:`in_flight` reads) or after ``max_epochs`` epochs,
        whichever first.  Stats accumulate in-carry exactly as under
        :meth:`run`; buffers are donated, so the input handle dies.

        Bit-exactness: a drained simulation's state is a fixpoint of the
        step (empty calendars process, route and deliver nothing), so
        stopping at the drain epoch k <= max_epochs yields the same
        calendars/state/stats as running the full bound — the sequential
        oracle at any horizon >= k compares bit-for-bit.  Non-draining
        workloads run exactly ``max_epochs`` epochs, identical to
        ``run(state, max_epochs)`` including the epoch counter.

        Use :meth:`run` to advance a fixed horizon (conformance sweeps,
        chunked inspection loops); use this to complete a simulation whose
        event population dies out (absorbing networks, exhausted budgets)
        without guessing an epoch count — and without paying per-chunk
        host dispatch.

        With ``cfg.opt_adaptive`` the drain runs in chunks through the
        adaptive-W controller instead of one fused dispatch: between chunks
        the host reads the observed ``rollbacks / spec_commits`` ratio and
        retunes the live window (``cfg.opt_window`` is the cap) — see
        :meth:`_run_drain_adaptive`.
        """
        self.check_stats_bound(max_epochs)
        if self.cfg.opt_adaptive and self.cfg.opt_window > 0:
            return self._run_drain_adaptive(state, max_epochs)
        self.dispatches += 1
        return self._drain_sm(state, jnp.int32(max_epochs))

    def _drain_variant(self, w: int):
        """The compiled fused-drain program for a live window width ``w``.

        Built (and cached) lazily: ``EngineState`` carries nothing W-shaped
        — the shadow copies live inside the step body — so the identical
        state flows through any variant and switching widths between chunks
        costs one compile per distinct width, ever.
        """
        if w not in self._drain_variants:
            cfg_w = dataclasses.replace(self.cfg, opt_window=w,
                                        opt_adaptive=False)
            step_w = make_spec_step(self.model, cfg_w, self.placement)

            def drain(state: EngineState, max_epochs: jax.Array) -> EngineState:
                bound = state.epoch[0] + max_epochs

                def in_flight_device(s: EngineState) -> jax.Array:
                    local = (jnp.sum(s.cal.cnt)
                             + jnp.sum(s.fb.events.valid.astype(jnp.int32)))
                    return jax.lax.psum(local, AXIS)

                def cond(carry):
                    s, pending = carry
                    return (pending > 0) & (s.epoch[0] < bound)

                def body(carry):
                    s, _ = carry
                    s = step_w(s, bound)
                    return s, in_flight_device(s)

                s, _ = jax.lax.while_loop(
                    cond, body, (state, in_flight_device(state)))
                return s

            spec = P(AXIS)
            self._drain_variants[w] = jax.jit(
                _shard_map(drain, self.mesh, (spec, P()), spec),
                donate_argnums=0)
        return self._drain_variants[w]

    def _run_drain_adaptive(self, state: EngineState,
                            max_epochs: int) -> EngineState:
        """Host-side adaptive-W drain: chunked dispatches, retuned between.

        Policy: after each chunk, read the chunk's rollback ratio
        ``rollbacks / (rollbacks + spec_commits)`` from the in-carry meters.
        Above 1/2 the window is mostly wasted work — shrink it (floor 1);
        below 1/10 stragglers are rare — grow it (cap ``cfg.opt_window``).
        Purely schedule-level control: any W sequence drains to the same
        bits (each chunk is itself a bit-exact fused drain), so the
        controller needs no correctness reasoning, only taste.  Each chunk
        is one honest host dispatch (``self.dispatches`` counts them).
        """
        W0 = self.cfg.opt_window
        w = W0
        # a chunk must be long enough to observe several windows at the
        # widest width, short enough to react — a few windows' worth.
        chunk = max(8, 4 * (W0 + 1))
        tot = self.totals(state)
        prev_cm, prev_rb = tot["spec_commits"], tot["rollbacks"]
        start_epoch = int(np.asarray(state.epoch)[0])
        while True:
            epochs_run = int(np.asarray(state.epoch)[0]) - start_epoch
            n = min(chunk, int(max_epochs) - epochs_run)
            self.dispatches += 1
            state = self._drain_variant(w)(state, jnp.int32(max(n, 0)))
            epochs_run = int(np.asarray(state.epoch)[0]) - start_epoch
            if (epochs_run >= int(max_epochs) or n <= 0
                    or self.in_flight(state) == 0):
                return state
            tot = self.totals(state)
            d_cm = tot["spec_commits"] - prev_cm
            d_rb = tot["rollbacks"] - prev_rb
            prev_cm, prev_rb = tot["spec_commits"], tot["rollbacks"]
            if d_cm + d_rb:
                ratio = d_rb / (d_rb + d_cm)
                if ratio > 0.5 and w > 1:
                    w -= 1
                elif ratio < 0.1 and w < W0:
                    w += 1

    def run_replicated_drained(self, state: EngineState,
                               max_epochs: int) -> EngineState:
        """Drain R independent replications as ONE XLA dispatch.

        ``state`` is the stacked carry of :meth:`init_replicated`; the fused
        ``lax.while_loop`` vmaps the epoch step over the replication axis and
        exits when *every* replication's in-flight count is zero (or at
        ``max_epochs``).  A replication that drains early is frozen in-carry
        — its epoch counter, Stats and object state stop at its own drain
        epoch — so each slice of the result is leaf-exact vs an independent
        ``run_until_drained`` of that seed (and therefore bit-exact vs its
        own sequential oracle for dyadic workloads).  Buffers are donated:
        rebind the result, the input handle dies.

        Read the result per replication with :meth:`replication`,
        :meth:`totals_replicated` and :meth:`in_flight_replicated`.
        """
        self.check_stats_bound(max_epochs)
        self.dispatches += 1
        return self._drain_rep_sm(state, jnp.int32(max_epochs))

    # -- inspection -------------------------------------------------------------

    def replication(self, state: EngineState, r: int) -> EngineState:
        """Slice replication ``r`` out of a stacked state — the result has
        the classic single-simulation layout, so every scalar inspection
        helper (:meth:`totals`, :meth:`in_flight`, ...) applies to it."""
        return jax.tree.map(lambda l: l[r], state)

    def totals_replicated(self, state: EngineState) -> list[dict[str, int]]:
        """Per-replication Stats totals of a stacked state, in seed order."""
        sums = {k: np.asarray(l).reshape(l.shape[0], -1).sum(axis=1)
                for k, l in state.stats._asdict().items()}
        return [{k: int(v[r]) for k, v in sums.items()}
                for r in range(state.epoch.shape[0])]

    def in_flight_replicated(self, state: EngineState) -> np.ndarray:
        """Per-replication in-flight event counts, i64[R]."""
        R = state.epoch.shape[0]
        cal = np.asarray(state.cal.cnt).reshape(R, -1).sum(axis=1)
        fb = np.asarray(state.fb.events.valid).reshape(R, -1).sum(axis=1)
        return (cal + fb).astype(np.int64)

    def totals(self, state: EngineState) -> dict[str, int]:
        st = jax.tree.map(lambda l: int(np.sum(np.asarray(l))), state.stats)
        return st._asdict()

    def in_flight(self, state: EngineState) -> int:
        cal = int(np.sum(np.asarray(state.cal.cnt)))
        fb = int(np.sum(np.asarray(state.fb.events.valid)))
        return cal + fb

    def occupancy(self, state: EngineState) -> dict[str, np.ndarray | int]:
        """Width-packing diagnostics for the *current* epoch's bucket.

        Per device: live event total (``events``), max per-object batch depth
        (``max_depth``), the dense rounds grid each device would execute
        (``padded_lanes = max_depth × n_local_max`` — every device pays its
        own grid, in lockstep until the collective), and the events actually
        present (``packed_lanes``, what ``batch_impl='packed'`` processes up
        to per-round tile rounding).  The padded-row tax is the gap.
        """
        M = self.placement.n_local_max
        depth = np.asarray(
            bucket_occupancy(state.cal, state.epoch[0])).reshape(self.D, M)
        events = depth.sum(axis=1)
        max_depth = depth.max(axis=1, initial=0)
        return {"events": events, "max_depth": max_depth,
                "padded_lanes": max_depth * M, "packed_lanes": events,
                "n_local_max": M}

    def boundaries_of(self, state: EngineState) -> np.ndarray:
        """The live placement boundaries, i64[D+1] (they move under
        ``placement='adaptive'``; rows of ``state.bounds`` are identical)."""
        return np.asarray(state.bounds)[0].astype(np.int64)

    def global_row_of(self, state: EngineState) -> tuple[np.ndarray, np.ndarray]:
        """(gid, live) per padded row, each [D * n_local_max].

        ``gid[r]`` is the global object id row ``r`` backs; ``live[r]`` is
        False for pad rows (which never hold events or meaningful state).
        """
        b = self.boundaries_of(state)
        M = self.placement.n_local_max
        d = np.arange(self.D * M) // M
        i = np.arange(self.D * M) % M
        gid = b[d] + i
        live = i < (b[d + 1] - b[d])
        return np.where(live, gid, 0), live

    def global_object_state(self, state: EngineState) -> dict[str, np.ndarray]:
        """Per-object state re-assembled in global id order, leading dim
        ``n_objects`` — the padded per-device layout undone."""
        gid, live = self.global_row_of(state)
        order = np.nonzero(live)[0]  # contiguous ranges → already gid-sorted
        assert np.array_equal(gid[order], np.arange(self.model.n_objects))
        return {k: np.asarray(v)[order] for k, v in state.obj.items()}
