"""The campaign loop: one vmapped fused-drain dispatch per grid point.

Grid-point parameters are trace-time constants (they change shapes, branch
structure, compiled code), so points run sequentially — but *within* a point
every replication seed is pure data, and all of them advance together through
:meth:`ParsirEngine.run_replicated_drained`: two host dispatches per point
(the ingest and the fused drain), independent of the seed count.

Every replication's counters are checked against the clean-run contract
(:mod:`repro.testing.clean`) and its drain status recorded; the point result
lands in the :class:`ResultsStore` before the next point compiles, so an
interrupted campaign resumes where it stopped.
"""
from __future__ import annotations

from typing import Any, Callable

from .spec import CampaignSpec
from .store import ResultsStore


def _run_point(spec: CampaignSpec, index: int, mesh,
               rep_shards: int | None = None) -> dict[str, Any]:
    import numpy as np

    from ..core.engine import EngineConfig, ParsirEngine
    from ..testing.clean import unclean_counters
    from ..workloads.registry import get_workload

    point = spec.points()[index]
    model = get_workload(spec.workload, **point)
    eng = ParsirEngine(model, EngineConfig(**spec.engine_kw), mesh=mesh,
                       rep_shards=rep_shards)

    base = eng.dispatches
    st = eng.init_replicated(spec.seeds)
    st = eng.run_replicated_drained(st, spec.max_epochs)

    totals = eng.totals_replicated(st)
    in_flight = eng.in_flight_replicated(st)
    epochs = np.asarray(st.epoch)[:, 0]
    reps = []
    for r, seed in enumerate(spec.seeds):
        reps.append({
            "seed": int(seed),
            "processed": totals[r]["processed"],
            "epochs": int(epochs[r]),
            "in_flight": int(in_flight[r]),
            "unclean": unclean_counters(totals[r]),
            "stats": totals[r],
        })
    return {
        "index": index,
        "label": spec.point_label(index),
        "model_kw": point,
        "seeds": [int(s) for s in spec.seeds],
        "max_epochs": spec.max_epochs,
        "dispatches": eng.dispatches - base,
        "drained": bool(int(in_flight.sum()) == 0),
        "replications": reps,
    }


def run_campaign(spec: CampaignSpec, store: ResultsStore | None = None,
                 mesh=None, log: Callable[[str], None] | None = None
                 ) -> dict[str, Any]:
    """Run (or resume) a campaign; return the summary dict.

    With a ``store``, completed grid points are skipped (their stored result
    is reused in the summary) and fresh results are written as they finish.
    ``mesh`` defaults to the first ``spec.devices`` visible JAX devices;
    with ``spec.devices > 1`` and a divisible seed count the replication
    axis is sharded across them (``rep_shards`` — each replication runs
    collective-free on its own device) rather than the object axis.

    The summary reports, per the clean-run contract, every replication with
    nonzero overflow/causality counters (``unclean``) and every grid point
    whose drain hit ``max_epochs`` with events still in flight
    (``undrained``) — drivers decide which of those are fatal.
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from ..core.engine import AXIS

    say = log or (lambda msg: None)
    rep_shards = None
    if mesh is None:
        devs = jax.devices()
        if len(devs) < spec.devices:
            raise ValueError(
                f"{len(devs)} devices visible, campaign wants {spec.devices} "
                f"— set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{spec.devices}")
        if spec.devices > 1 and len(spec.seeds) % spec.devices == 0:
            # the campaign throughput layout: shard the REPLICATION axis —
            # each replication runs whole (collective-free) on its own
            # device, which beats object-sharding whenever one replication
            # fits a device.  Falls back to object-sharding when the seed
            # count doesn't divide (or a caller supplied its own mesh).
            rep_shards = spec.devices
            mesh = Mesh(np.array(devs[:1]), (AXIS,))
        else:
            mesh = Mesh(np.array(devs[:spec.devices]), (AXIS,))

    if store is not None:
        store.write_manifest(spec)

    points = spec.points()
    results, ran, resumed = [], 0, 0
    for i in range(len(points)):
        if store is not None and store.has(spec, i):
            results.append(store.get(spec, i))
            resumed += 1
            say(f"[campaign] point {i} ({spec.point_label(i)}): resumed")
            continue
        res = _run_point(spec, i, mesh, rep_shards)
        if store is not None:
            store.put(spec, i, res)
        results.append(res)
        ran += 1
        done = sum(r["processed"] for r in res["replications"])
        say(f"[campaign] point {i} ({res['label']}): {done} events over "
            f"{len(spec.seeds)} seeds, {res['dispatches']} dispatches, "
            f"drained={res['drained']}")

    unclean = [(res["index"], rep["seed"], rep["unclean"])
               for res in results for rep in res["replications"]
               if rep["unclean"]]
    undrained = [res["index"] for res in results if not res["drained"]]
    return {
        "digest": spec.digest(),
        "n_points": len(points),
        "ran": ran,
        "resumed": resumed,
        "missing": store.missing(spec) if store is not None else [],
        "unclean": unclean,
        "undrained": undrained,
        "results": results,
    }
