"""Digest-keyed results store with resumable campaign runs.

Layout under the store root::

    <root>/<digest12>/manifest.json    — the spec (canonical dict), full
                                         digest, and the git commit the run
                                         started from
    <root>/<digest12>/point-<i>.json   — one result per grid point, indexed
                                         by the spec's deterministic
                                         enumeration (spec.points())

Keying the run directory by the spec digest makes resumption safe by
construction: a re-run of the *same* spec skips every ``point-<i>.json``
already present, while any change to the spec (grid, seeds, engine config)
changes the digest and starts a fresh directory — stale results can never be
mistaken for the new campaign's.  The manifest's commit records provenance
only; it deliberately does not key the directory (a reproducible spec should
resume across commits — bit-exactness is the engine's contract, and the
conformance suite enforces it).
"""
from __future__ import annotations

import json
import subprocess
from pathlib import Path
from typing import Any

from .spec import CampaignSpec


def git_commit(cwd: str | None = None) -> str:
    """The current git HEAD (``+dirty`` if the tree has uncommitted
    changes), or ``"unknown"`` outside a checkout.

    The dirty marker matters for provenance: a manifest recording a bare
    commit hash claims "this campaign ran the committed code", which is a
    false claim from a modified working tree — resuming a campaign after
    an innocent-looking local edit would silently mix results from two
    different programs under one commit id.
    """
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=cwd,
                             capture_output=True, text=True, timeout=10)
        if out.returncode != 0:
            return "unknown"
        head = out.stdout.strip()
        status = subprocess.run(["git", "status", "--porcelain"], cwd=cwd,
                                capture_output=True, text=True, timeout=10)
        # a failed status check must not report a clean tree — fall back to
        # the marker (provenance may only ever err toward "dirty").
        if status.returncode != 0 or status.stdout.strip():
            return head + "+dirty"
        return head
    except OSError:
        return "unknown"


class ResultsStore:
    """One directory per campaign digest; one JSON file per grid point."""

    def __init__(self, root: str | Path):
        self.root = Path(root)

    def run_dir(self, spec: CampaignSpec) -> Path:
        return self.root / spec.digest()[:12]

    def _point_path(self, spec: CampaignSpec, index: int) -> Path:
        return self.run_dir(spec) / f"point-{index}.json"

    # -- manifest -----------------------------------------------------------

    def write_manifest(self, spec: CampaignSpec) -> dict[str, Any]:
        """Create the run directory + manifest (idempotent; an existing
        manifest is verified against the spec digest, never overwritten)."""
        d = self.run_dir(spec)
        d.mkdir(parents=True, exist_ok=True)
        path = d / "manifest.json"
        if path.exists():
            manifest = json.loads(path.read_text())
            if manifest["digest"] != spec.digest():
                raise ValueError(
                    f"{path} holds a different campaign "
                    f"(digest {manifest['digest'][:12]}, "
                    f"expected {spec.digest()[:12]})")
            return manifest
        manifest = {"digest": spec.digest(), "commit": git_commit(),
                    "n_points": len(spec.points()), "spec": spec.as_dict()}
        path.write_text(json.dumps(manifest, indent=2, sort_keys=True))
        return manifest

    # -- per-point results --------------------------------------------------

    def has(self, spec: CampaignSpec, index: int) -> bool:
        """True iff the point is stored AND parses as JSON.

        Existence alone is not enough for the resume contract: a run killed
        mid-write outside :meth:`put`'s atomic rename path (or a truncated
        copy/restore) can leave a zero-byte or corrupt ``point-<i>.json``,
        and treating it as done would silently hole the campaign.  Corrupt
        points read as absent, so ``missing()`` schedules a re-run.
        """
        path = self._point_path(spec, index)
        try:
            json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return False
        return True

    def get(self, spec: CampaignSpec, index: int) -> dict[str, Any]:
        path = self._point_path(spec, index)
        try:
            return json.loads(path.read_text())
        except FileNotFoundError:
            raise KeyError(
                f"campaign {spec.digest()[:12]} has no stored point "
                f"{index} (expected {path}); run the campaign (or check "
                f"missing()) before reading results") from None

    def put(self, spec: CampaignSpec, index: int,
            result: dict[str, Any]) -> None:
        path = self._point_path(spec, index)
        tmp = path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(result, indent=2, sort_keys=True))
        tmp.replace(path)       # atomic: a crash never leaves a half entry

    def missing(self, spec: CampaignSpec) -> list[int]:
        """Grid-point indices not yet stored — empty iff the campaign is
        complete (the CLI's exit criterion)."""
        return [i for i in range(len(spec.points()))
                if not self.has(spec, i)]
