"""The declarative campaign grid: seeds × model kwargs × engine config.

A :class:`CampaignSpec` is pure data, canonically serializable, and hashable
by content: :meth:`CampaignSpec.digest` is the sha256 of its canonical JSON,
so the results store can key a run directory by *what was asked for* — the
same spec always lands in the same directory (resumable), and any change to
the grid, the seeds or the engine config starts a fresh one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any


def _canonical(obj: Any) -> str:
    """Deterministic JSON: sorted keys, no whitespace variance."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """A parameter sweep: every grid point runs every seed.

    ``grid`` maps model-kwarg names to value lists; :meth:`points` is their
    cartesian product merged over ``base_model_kw`` (grid wins).  ``seeds``
    are the replication seeds every point runs — stacked into one vmapped
    drain dispatch by the runner.  ``engine_kw`` feeds ``EngineConfig``
    verbatim; ``max_epochs`` bounds each point's fused drain.
    """

    workload: str
    seeds: tuple[int, ...]
    base_model_kw: dict[str, Any] = dataclasses.field(default_factory=dict)
    grid: dict[str, list] = dataclasses.field(default_factory=dict)
    engine_kw: dict[str, Any] = dataclasses.field(default_factory=dict)
    devices: int = 1
    max_epochs: int = 256

    def __post_init__(self):
        if not self.seeds:
            raise ValueError("a campaign needs at least one seed")
        if len(set(self.seeds)) != len(self.seeds):
            raise ValueError(f"duplicate seeds: {self.seeds}")
        clash = set(self.grid) & set(self.base_model_kw)
        if clash:
            raise ValueError(f"grid keys shadow base_model_kw: {sorted(clash)}")
        for k, vs in self.grid.items():
            if not vs:
                raise ValueError(f"grid axis {k!r} has no values")

    def points(self) -> list[dict[str, Any]]:
        """The grid's cartesian product as model-kwarg dicts, in the
        deterministic (sorted-key, given-value-order) enumeration the store
        indexes by."""
        keys = sorted(self.grid)
        out = []
        for combo in itertools.product(*(self.grid[k] for k in keys)):
            point = dict(self.base_model_kw)
            point.update(zip(keys, combo))
            out.append(point)
        return out

    def point_label(self, index: int) -> str:
        """Human-readable label of grid point ``index`` (varied axes only)."""
        keys = sorted(self.grid)
        if not keys:
            return "base"
        point = self.points()[index]
        return ",".join(f"{k}={point[k]}" for k in keys)

    def as_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["seeds"] = list(self.seeds)
        return d

    def digest(self) -> str:
        """sha256 over the canonical JSON of the whole spec."""
        return hashlib.sha256(_canonical(self.as_dict()).encode()).hexdigest()
