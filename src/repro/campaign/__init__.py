"""Campaign engine: whole parameter sweeps as single-dispatch simulations.

A *campaign* is a grid of model-parameter points × a set of replication
seeds.  Parameters select distinct compiled programs (they are trace-time
constants — different shapes, branches, weights), while seeds are pure data:
all of a point's replications run stacked through the engine's
replication-vmapped fused drain (:meth:`ParsirEngine.run_replicated_drained`)
— ONE XLA dispatch per parameter point, regardless of the seed count.

Modules:
  * :mod:`repro.campaign.spec`   — :class:`CampaignSpec`: the declarative
    grid (seeds × model kwargs × engine config), canonically digestible;
  * :mod:`repro.campaign.store`  — :class:`ResultsStore`: one JSON per grid
    point under a digest-keyed run directory, with the spec + git commit in a
    manifest — re-running a campaign skips completed points (resumability);
  * :mod:`repro.campaign.runner` — :func:`run_campaign`: the loop that wires
    them to the engine.

The CLI face is :mod:`repro.launch.campaign`.
"""
from .spec import CampaignSpec
from .store import ResultsStore
from .runner import run_campaign

__all__ = ["CampaignSpec", "ResultsStore", "run_campaign"]
