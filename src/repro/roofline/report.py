"""Build the §Roofline table from dry-run artifacts.

  PYTHONPATH=src python -m repro.roofline.report [--mesh single] [--md out.md]

Per cell: the three roofline terms (seconds), dominant bottleneck, MODEL_FLOPS
ratio, roofline fraction, and a what-would-move-it note.  jaxpr FLOP counts
are cached under artifacts/roofline/.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from ..configs.base import SHAPES
from ..configs.registry import get_config
from . import analysis


def _note(row: dict, rec: dict, cfg) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_flops_ratio"] < 0.5:
            return ("compute-bound with low useful-FLOP ratio: cut remat "
                    "recompute (remat='dots') / avoid duplicated expert math")
        return "compute-bound near useful peak: only faster kernels help"
    if d == "memory":
        return ("HBM-bound: shrink cache/activation dtype (bf16/f8), fuse "
                "reads, or raise arithmetic intensity (larger per-chip tiles)")
    return ("collective-bound: reshard to cut per-layer all-gathers, overlap "
            "collectives with compute, or move traffic off the layer loop")


def cell_flops(arch: str, shape: str, cache_dir: Path) -> float:
    cache_dir.mkdir(parents=True, exist_ok=True)
    f = cache_dir / f"flops__{arch}__{shape}.json"
    if f.exists():
        return json.loads(f.read_text())["flops"]
    val = analysis.count_cell_flops(arch, shape)
    f.write_text(json.dumps({"flops": val}))
    return val


def build_rows(artifact_dir: Path, mesh: str, cache_dir: Path):
    rows = []
    for path in sorted(artifact_dir.glob(f"*__{mesh}.json")):
        rec = json.loads(path.read_text())
        arch, shape = rec["arch"], rec["shape"]
        if rec["status"] != "ok":
            rows.append({"arch": arch, "shape": shape,
                         "status": rec["status"],
                         "note": rec.get("skip_reason", rec.get("error", ""))})
            continue
        cfg = get_config(arch)
        flops = cell_flops(arch, shape, cache_dir)
        rec["analytic_memory_floor"] = analysis.analytic_memory_floor(arch,
                                                                      shape)
        trip = cfg.n_layers if cfg.scan_layers and cfg.family in (
            "dense", "moe") else 1
        mf = analysis.model_flops_for(arch, shape)
        row = analysis.roofline_row(rec, flops_global=flops,
                                    chips=rec["n_devices"], trip=trip,
                                    model_flops=mf, kind=SHAPES[shape].kind)
        row.update({"arch": arch, "shape": shape, "status": "ok",
                    "compile_s": rec.get("compile_s")})
        row["note"] = _note(row, rec, cfg)
        rows.append(row)
    return rows


def to_markdown(rows, mesh: str) -> str:
    out = [f"### Roofline — {mesh}-pod mesh\n",
           "| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful-FLOP ratio | roofline frac | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — | {r.get('note','')[:80]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['note'][:90]} |")
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    rows = build_rows(Path(args.artifacts), args.mesh,
                      Path("artifacts/roofline"))
    md = to_markdown(rows, args.mesh)
    print(md)
    if args.md:
        Path(args.md).write_text(md)
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1, default=str))


if __name__ == "__main__":
    main()
