"""§Dry-run summary table from artifacts/dryrun/*.json.

  PYTHONPATH=src python -m repro.roofline.dryrun_summary [--md out.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

HBM_PER_CHIP = 16e9  # v5e


def gb(x):
    return f"{x / 1e9:.2f}"


def build(artifact_dir: Path) -> str:
    rows = []
    for path in sorted(artifact_dir.glob("*.json")):
        rec = json.loads(path.read_text())
        if "__" not in path.stem:
            continue
        name = f"{rec['arch']} × {rec['shape']}"
        mesh = rec["mesh"]
        variant = rec.get("overrides")
        if variant or path.stem.count("__") > 2:
            continue  # hillclimb variants reported in §Perf
        if rec["status"] == "skipped":
            rows.append((name, mesh, "skipped", "—", "—", "—", "—",
                         rec.get("skip_reason", "")[:60]))
            continue
        if rec["status"] != "ok":
            rows.append((name, mesh, "ERROR", "—", "—", "—", "—",
                         rec.get("error", "")[:60]))
            continue
        args = rec.get("argument_size_in_bytes", 0)
        temp = rec.get("temp_size_in_bytes", 0)
        fits = "yes" if (args + temp) <= HBM_PER_CHIP else \
            f"no ({gb(args + temp)} GB)"
        coll = rec.get("collectives", {})
        ctypes = ",".join(k for k, v in coll.items() if v["count"])
        rows.append((name, mesh, "ok", f"{rec.get('compile_s', 0):.0f}s",
                     gb(args), gb(temp), fits, ctypes))

    out = ["| arch × shape | mesh | status | compile | args GB/chip | "
           "temp GB/chip | fits 16GB | collectives |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    md = build(Path(args.artifacts))
    print(md)
    if args.md:
        Path(args.md).write_text(md)


if __name__ == "__main__":
    main()
