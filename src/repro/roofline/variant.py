"""Roofline row for a hillclimb variant artifact.

  PYTHONPATH=src python -m repro.roofline.variant artifacts/dryrun/<cell>.json
"""
import json
import sys
from pathlib import Path

from ..configs.registry import get_config
from . import analysis


def row_for(path: str) -> dict:
    rec = json.loads(Path(path).read_text())
    arch, shape = rec["arch"], rec["shape"]
    overrides = rec.get("overrides")
    flops = analysis.count_cell_flops(arch, shape, overrides=overrides)
    rec["analytic_memory_floor"] = analysis.analytic_memory_floor(arch, shape)
    cfg = get_config(arch)
    trip = cfg.n_layers
    mf = analysis.model_flops_for(arch, shape)
    from ..configs.base import SHAPES
    row = analysis.roofline_row(rec, flops_global=flops,
                                chips=rec["n_devices"], trip=trip,
                                model_flops=mf, kind=SHAPES[shape].kind)
    row.update({"arch": arch, "shape": shape,
                "variant": Path(path).stem.split("__")[-1],
                "overrides": overrides})
    return row


def main():
    for path in sys.argv[1:]:
        r = row_for(path)
        print(f"{r['arch']} x {r['shape']} [{r['variant']}]")
        print(f"  compute {r['compute_s']:.4f}s  memory {r['memory_s']:.4f}s  "
              f"collective {r['collective_s']:.4f}s  -> {r['dominant']}")
        print(f"  useful-FLOP ratio {r['useful_flops_ratio']:.3f}  "
              f"roofline fraction {r['roofline_fraction']:.4f}")
        print(f"  collectives: "
              + ", ".join(f"{k}={v/1e9:.1f}GB"
                          for k, v in r["collectives_scaled"].items()
                          if k != "total" and v > 0))


if __name__ == "__main__":
    main()
