"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the assignment:

    compute    = FLOPs / (chips * 197e12)            [bf16 peak/chip, v5e]
    memory     = HBM bytes / (chips * 819e9)
    collective = collective bytes per chip / 50e9    [ICI link bw]

Methodology notes (calibrated, see EXPERIMENTS.md):
  * XLA's HLO cost_analysis counts a `while` (scan) body ONCE, so its FLOPs
    undercount scanned-layer models by ~n_layers x.  The compute term
    therefore uses an exact jaxpr-level counter (`jaxpr_flops`) that walks the
    traced program, multiplies scan bodies by their trip counts, and counts
    remat recompute (it appears explicitly in the grad jaxpr).
  * the memory term takes the max of HLO "bytes accessed" (fusion-aware but
    scan-undercounted) and an analytic floor (param/optimizer/grad traffic +
    batch + caches), each divided across chips.
  * the collective term uses the region-aware HLO parse: collectives inside
    while bodies are scaled by the layer-scan trip count.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax import core

HW = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip (TPU v5e)
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
}


# ---------------------------------------------------------------------------
# jaxpr-level FLOP counter (scan- and remat-aware)
# ---------------------------------------------------------------------------

def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    b = float(np.prod([lhs[i] for i in lb])) if lb else 1.0
    k = float(np.prod([lhs[i] for i in lc])) if lc else 1.0
    m = float(np.prod([s for i, s in enumerate(lhs)
                       if i not in lc and i not in lb]))
    n = float(np.prod([s for i, s in enumerate(rhs)
                       if i not in rc and i not in rb]))
    return 2.0 * b * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    groups = eqn.params.get("feature_group_count", 1)
    kernel = float(np.prod(rhs.shape)) / max(groups, 1)
    # 2 * output elements * (kernel work per output channel)
    per_out = kernel / max(rhs.shape[eqn.params["dimension_numbers"]
                                     .rhs_spec[0]], 1)
    return 2.0 * float(np.prod(out.shape)) * per_out


def _out_elems(eqn) -> float:
    tot = 0.0
    for v in eqn.outvars:
        aval = v.aval
        if hasattr(aval, "shape"):
            tot += float(np.prod(aval.shape)) if aval.shape else 1.0
    return tot


_TRANSCENDENTAL = {"exp", "log", "log1p", "tanh", "logistic", "erf", "sin",
                   "cos", "rsqrt", "sqrt", "pow", "exp2"}
_ZERO_COST = {"reshape", "transpose", "broadcast_in_dim", "convert_element_type",
              "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
              "gather", "scatter", "scatter-add", "iota", "squeeze", "copy",
              "stop_gradient", "device_put", "split", "pad", "rev",
              "bitcast_convert_type", "and", "or", "not", "xor", "select_n",
              "eq", "ne", "lt", "le", "gt", "ge", "sign", "argmax", "argmin",
              "reduce_precision", "real", "imag", "shift_left",
              "shift_right_logical", "shift_right_arithmetic", "clamp",
              "is_finite", "round", "floor", "ceil", "sort", "top_k",
              "random_bits", "random_seed", "random_wrap", "random_fold_in"}


def jaxpr_flops(jaxpr, depth: int = 0) -> float:
    """Total FLOPs of a (Closed)Jaxpr, multiplying scan bodies by length."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif name == "scan":
            body = jaxpr_flops(eqn.params["jaxpr"], depth + 1)
            total += body * float(eqn.params["length"])
        elif name == "while":
            # not used by model code (bounded scans only); count once.
            total += jaxpr_flops(eqn.params["body_jaxpr"], depth + 1)
        elif name == "cond":
            total += max(jaxpr_flops(b, depth + 1)
                         for b in eqn.params["branches"])
        elif name in _ZERO_COST:
            pass
        else:
            recursed = False
            for pname in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if pname in eqn.params:
                    sub = eqn.params[pname]
                    if sub is not None:
                        total += jaxpr_flops(sub, depth + 1)
                        recursed = True
                        break
            if not recursed and "branches" in eqn.params:
                total += max(jaxpr_flops(b, depth + 1)
                             for b in eqn.params["branches"])
                recursed = True
            if not recursed:
                mult = 4.0 if name in _TRANSCENDENTAL else 1.0
                total += mult * _out_elems(eqn)
    return total


def count_cell_flops(arch: str, shape_name: str,
                     overrides: dict | None = None) -> float:
    """Exact global FLOPs of the cell's step function (train/prefill/decode)."""
    from ..configs.base import TrainConfig
    from ..launch.specs import input_specs
    from ..serve.engine import make_decode_step, make_prefill
    from ..train.step import make_train_step

    spec = input_specs(arch, shape_name, overrides=overrides)
    model = spec["model"]
    if spec["kind"] == "train":
        tkw = {k[6:]: v for k, v in (overrides or {}).items()
               if k.startswith("train.")}
        fn = make_train_step(model, TrainConfig(**tkw))
        jx = jax.make_jaxpr(fn)(spec["params"], spec["opt_state"],
                                spec["batch"])
    elif spec["kind"] == "prefill":
        fn = make_prefill(model)
        jx = jax.make_jaxpr(fn)(spec["params"], spec["batch"], spec["caches"])
    else:
        fn = make_decode_step(model)
        cur = jax.ShapeDtypeStruct((), np.int32)
        jx = jax.make_jaxpr(fn)(spec["params"], spec["tokens"],
                                spec["caches"], cur)
    return jaxpr_flops(jx)


# ---------------------------------------------------------------------------
# roofline terms from a dry-run artifact
# ---------------------------------------------------------------------------

def _bytes_of(spec_tree) -> float:
    return float(sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize
                     for l in jax.tree.leaves(spec_tree)))


def analytic_memory_floor(arch: str, shape_name: str) -> float:
    """Minimum HBM traffic per step, bytes (global): params read + grads/opt
    write (train), or params+cache read/write (serve)."""
    from ..launch.specs import input_specs
    spec = input_specs(arch, shape_name)
    pbytes = _bytes_of(spec["params"])
    if spec["kind"] == "train":
        obytes = _bytes_of(spec["opt_state"])
        bbytes = _bytes_of(spec["batch"])
        # read params+opt, write params+opt, read/write grads once
        return 2 * pbytes + 2 * obytes + 2 * pbytes + bbytes
    cbytes = _bytes_of(spec["caches"])
    if spec["kind"] == "prefill":
        return pbytes + 2 * cbytes + _bytes_of(spec["batch"])
    return pbytes + cbytes + cbytes / max(1, 1)  # decode: read cache, write slot


def scaled_collective_bytes(rec: dict, trip: int) -> dict:
    """Trip-count-corrected collective bytes.  Prefers the exact per-while
    multipliers recorded by the dry-run parser (``scaled_bytes``); falls back
    to the uniform layer-scan correction for legacy artifacts."""
    out = {}
    tot = 0.0
    for c, v in rec.get("collectives", {}).items():
        if "scaled_bytes" in v:
            scaled = v["scaled_bytes"]
        else:
            in_loop = v.get("in_loop_bytes", 0)
            scaled = (v["bytes"] - in_loop) + in_loop * trip
        out[c] = scaled
        tot += scaled
    out["total"] = tot
    return out


def roofline_row(rec: dict, *, flops_global: float, chips: int,
                 trip: int, model_flops: float,
                 kind: str = "train") -> dict:
    compute_s = flops_global / (chips * HW["peak_flops"])

    hlo_bytes = rec.get("cost_analysis", {}).get("bytes accessed", 0.0)
    floor_global = rec.get("analytic_memory_floor", 0.0)
    mem_per_chip = max(hlo_bytes, floor_global / chips)
    memory_s = mem_per_chip / HW["hbm_bw"]

    coll = scaled_collective_bytes(rec, trip)
    collective_s = coll["total"] / HW["ici_bw"]

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful_ratio = model_flops / flops_global if flops_global else 0.0
    if kind == "decode":
        # decode is bandwidth-bound by nature: the roofline reference is the
        # minimum HBM time (params + cache must stream once per token), not
        # the (tiny) per-token matmul time.
        ideal_s = (floor_global / chips) / HW["hbm_bw"]
    else:
        ideal_s = model_flops / (chips * HW["peak_flops"])
    frac = ideal_s / bound if bound > 0 else 0.0
    return {**terms, "dominant": dominant.replace("_s", ""),
            "model_flops": model_flops, "hlo_jaxpr_flops": flops_global,
            "useful_flops_ratio": useful_ratio,
            "roofline_fraction": frac, "ideal_s": ideal_s,
            "collectives_scaled": coll}


def model_flops_for(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS: 6·N·D for train (N active for MoE); 2·N·D for inference."""
    from ..configs.base import SHAPES
    from ..configs.registry import get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
