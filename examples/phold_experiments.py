"""End-to-end driver (the paper's kind: run a full simulation campaign).

Reproduces the STRUCTURE of the paper's Figs 2-4 on CPU-scaled PHOLD
configurations, printing the tables the paper plots.

  PYTHONPATH=src python examples/phold_experiments.py [--fast]
"""
import argparse
import sys

sys.path.insert(0, "benchmarks")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    from common import build, throughput  # benchmarks/common.py

    epochs = 15 if args.fast else 40

    print("== Fig 2: throughput vs lookahead L and population M ==")
    print(f"{'L':>5} {'M':>6} {'events/s':>12}")
    for m in (10, 100):
        for la in (0.1, 0.5, 1.0):
            eng = build(o=256, m=m, s=256, lookahead=la, dist="exponential",
                        bucket_cap=max(64, 4 * m))
            ev_s, n, dt, clean = throughput(eng, warmup_epochs=3,
                                            epochs=epochs)
            flag = "" if clean else "  [capacity overflow!]"
            print(f"{la:>5} {m:>6} {ev_s:>12,.0f}{flag}")

    print("\n== Fig 4: throughput vs model size O (fixed workers) ==")
    print(f"{'O':>6} {'events/s':>12}")
    for o in (128, 256, 512, 1024):
        eng = build(o=o, m=20, s=256, lookahead=0.5, dist="exponential")
        ev_s, n, dt, clean = throughput(eng, warmup_epochs=3, epochs=epochs)
        print(f"{o:>6} {ev_s:>12,.0f}")

    print("\n(strong scaling over worker counts: "
          "PYTHONPATH=src python -m benchmarks.run — fig3 rows)")


if __name__ == "__main__":
    main()
