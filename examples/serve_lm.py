"""Serve a (reduced) LM with batched requests: prefill + greedy decode.

  PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-3b --tokens 16
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.data.synthetic import make_batch
from repro.models.registry import build_model
from repro.serve.engine import ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, args.batch, args.prompt_len)

    sess = ServeSession(model, params, args.batch,
                        max_len=args.prompt_len + args.tokens + 1,
                        dtype=np.float32)
    t0 = time.perf_counter()
    first = sess.prefill(batch)
    t1 = time.perf_counter()
    out = sess.decode(first, args.tokens - 1)
    t2 = time.perf_counter()

    total = args.batch * (args.tokens - 1)
    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    print(f"prefill: {1e3*(t1-t0):.0f} ms; decode: {1e3*(t2-t1):.0f} ms "
          f"({total/(t2-t1):,.0f} tok/s incl. compile)")
    print("sampled continuations (token ids):")
    for b in range(args.batch):
        print(f"  req{b}: {[int(first[b])] + out[b].tolist()}")


if __name__ == "__main__":
    main()
