"""Quickstart: run a PHOLD model on the PARSIR engine and verify it against
the sequential oracle.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core.engine import EngineConfig, ParsirEngine
from repro.core.ref_engine import run_sequential
from repro.phold.model import Phold, PholdParams


def main():
    params = PholdParams(n_objects=64, initial_events=8, state_nodes=256,
                         realloc_fraction=0.01, lookahead=0.5, dist="dyadic")
    model = Phold(params)
    cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=128,
                       route_cap=2048, fallback_cap=2048)
    eng = ParsirEngine(model, cfg)

    state = eng.init()
    print(f"initialized: {eng.in_flight(state)} events in flight "
          f"(= O*M = {params.n_objects * params.initial_events})")

    n_epochs = 40
    t0 = time.perf_counter()
    state = eng.run(state, n_epochs)
    dt = time.perf_counter() - t0
    tot = eng.totals(state)
    print(f"ran {n_epochs} epochs in {dt:.2f}s -> "
          f"{tot['processed'] / dt:,.0f} events/s")
    print(f"stats: {tot}")

    ref = run_sequential(model, n_epochs, cfg.epoch_len)
    assert tot["processed"] == ref.total_processed
    pay = np.asarray(state.obj["payload"])
    ref_pay = np.stack([s["payload"] for s in ref.obj_state])
    assert np.array_equal(pay, ref_pay), "state mismatch!"
    print("parallel engine == sequential oracle (bit-exact) ✓")


if __name__ == "__main__":
    main()
