"""Cluster simulator: use the PARSIR core to simulate a multi-pod training
fleet (the PARADISE++-style use-case from the paper's related work).

The model itself now lives in the workload zoo
(:mod:`repro.workloads.cluster` — with a numpy oracle mirror and conformance
coverage); this example keeps the fleet-sizing experiment: measure achieved
steps/hour vs node failure rate, the quantity that sizes checkpoint
intervals on a real fleet.

  PYTHONPATH=src python examples/cluster_sim.py
"""
import numpy as np

from repro.core.engine import EngineConfig, ParsirEngine
from repro.workloads.cluster import ClusterModel, ClusterParams


def run(fail_ppm, n_epochs=400):
    model = ClusterModel(ClusterParams(n_nodes=64, n_rings=8,
                                       fail_ppm=fail_ppm, dist="uniform24"))
    cfg = EngineConfig(lookahead=model.params.lookahead, n_buckets=64,
                       bucket_cap=32, route_cap=1024, fallback_cap=4096)
    eng = ParsirEngine(model, cfg)
    st = eng.run(eng.init(), n_epochs)
    tot = eng.totals(st)
    hops = int(np.asarray(st.obj["hops"]).sum())
    fails = int(np.asarray(st.obj["failures"]).sum())
    sim_time = n_epochs * cfg.epoch_len
    steps = hops / 64  # one "global step" per full ring rotation per ring
    assert tot["late_events"] == 0 and tot["cal_overflow"] == 0
    return steps / sim_time, fails, hops


def main():
    print("failure-rate sweep: training goodput vs node failure probability")
    print(f"{'fail/M hops':>12} {'steps/sim-h':>12} {'failures':>9} "
          f"{'hops':>8}")
    base = None
    for ppm in (0, 5000, 20000, 80000):
        rate, fails, hops = run(ppm)
        base = base or rate
        print(f"{ppm:>12} {rate*3600:>12.1f} {fails:>9} {hops:>8} "
              f"(goodput {100*rate/base:.0f}%)")
    print("\n→ with the measured goodput curve, pick checkpoint interval "
          "t_ckpt ≈ sqrt(2·t_write·MTBF) (Young/Daly) per fleet size.")


if __name__ == "__main__":
    main()
