"""Cluster simulator: use the PARSIR core to simulate a multi-pod training
fleet (the PARADISE++-style use-case from the paper's related work).

Model: ``n_nodes`` workers run synchronous data-parallel training as a token
ring (the token models the allreduce dependency).  Each hop costs a step time
drawn from the event seed; with probability ~p_fail the hop instead suffers a
failure + restart delay.  The simulation measures achieved steps/hour vs the
failure rate — the quantity that sizes checkpoint intervals on a real fleet.

This is a SECOND SimModel (beyond PHOLD) demonstrating that the engine API is
model-agnostic: ScheduleNewEvent ≅ returned EmittedEvents, ProcessEvent ≅
process_event.

  PYTHONPATH=src python examples/cluster_sim.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.api import EmittedEvents, SimModel
from repro.core.engine import EngineConfig, ParsirEngine


class ClusterModel(SimModel):
    """Objects = worker nodes in a ring; one token event per ring."""

    max_out = 1

    def __init__(self, n_nodes=64, n_rings=8, step_time=1.0, fail_ppm=20000,
                 restart_time=25.0, lookahead=0.5):
        self._n = n_nodes
        self.n_rings = n_rings
        self.step_time = step_time
        self.fail_ppm = fail_ppm          # failures per million hops
        self.restart_time = restart_time
        self.lookahead = lookahead

    @property
    def n_objects(self):
        return self._n

    def init_object_state(self, global_ids):
        n = len(global_ids)
        return {"hops": jnp.zeros((n,), jnp.int32),
                "failures": jnp.zeros((n,), jnp.int32),
                "busy_time": jnp.zeros((n,), jnp.float32)}

    def initial_events(self):
        # n_rings tokens start at evenly spaced nodes
        starts = (np.arange(self.n_rings) * (self._n // self.n_rings)) \
            % self._n
        s0 = ev._mix_np(np.arange(self.n_rings).astype(np.uint32)
                        ^ np.uint32(0xC1A07E57))
        return {"dst": starts.astype(np.int32),
                "ts": np.zeros(self.n_rings, np.float32),
                "seed": s0,
                "payload": np.zeros(self.n_rings, np.float32)}

    def process_event(self, state, ts, seed, payload):
        u = ev.uniform24(ev.fold(seed, 0))
        fail = (ev.fold(seed, 1) % jnp.uint32(1_000_000)) \
            < jnp.uint32(self.fail_ppm)
        hop = jnp.float32(self.lookahead) + jnp.float32(self.step_time) * u
        delay = jnp.where(fail, hop + jnp.float32(self.restart_time), hop)

        state = {"hops": state["hops"] + 1,
                 "failures": state["failures"] + fail.astype(jnp.int32),
                 "busy_time": state["busy_time"] + delay}
        # forward token to the ring neighbour (dst = self+1 handled globally
        # by the engine's routing — locality exactly like NUMA-remote enqueue)
        me = payload.astype(jnp.int32)  # payload carries my id
        nxt = (me + 1) % self._n
        out = EmittedEvents(dst=nxt[None], ts=(ts + delay)[None],
                            seed=ev.fold(seed, 3)[None],
                            payload=nxt.astype(jnp.float32)[None],
                            valid=jnp.ones((1,), bool))
        return state, out


def run(fail_ppm, n_epochs=400):
    model = ClusterModel(n_nodes=64, n_rings=8, fail_ppm=fail_ppm)
    # seed payload with node ids: patch initial events
    cfg = EngineConfig(lookahead=model.lookahead, n_buckets=64,
                       bucket_cap=32, route_cap=1024, fallback_cap=4096)
    init = model.initial_events()
    init["payload"] = init["dst"].astype(np.float32)
    model.initial_events = lambda: init
    eng = ParsirEngine(model, cfg)
    st = eng.run(eng.init(), n_epochs)
    tot = eng.totals(st)
    hops = int(np.asarray(st.obj["hops"]).sum())
    fails = int(np.asarray(st.obj["failures"]).sum())
    sim_time = n_epochs * cfg.epoch_len
    steps = hops / 64  # one "global step" per full ring rotation per ring
    assert tot["late_events"] == 0 and tot["cal_overflow"] == 0
    return steps / sim_time, fails, hops


def main():
    print("failure-rate sweep: training goodput vs node failure probability")
    print(f"{'fail/M hops':>12} {'steps/sim-h':>12} {'failures':>9} "
          f"{'hops':>8}")
    base = None
    for ppm in (0, 5000, 20000, 80000):
        rate, fails, hops = run(ppm)
        base = base or rate
        print(f"{ppm:>12} {rate*3600:>12.1f} {fails:>9} {hops:>8} "
              f"(goodput {100*rate/base:.0f}%)")
    print("\n→ with the measured goodput curve, pick checkpoint interval "
          "t_ckpt ≈ sqrt(2·t_write·MTBF) (Young/Daly) per fleet size.")


if __name__ == "__main__":
    main()
