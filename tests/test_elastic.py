"""Elastic scaling: a checkpoint written under one topology restores onto a
different mesh (the ft/ reshard path) and training continues identically."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.synthetic import SyntheticLoader
from repro.models.registry import build_model
from repro.train.loop import Trainer

_CHILD = textwrap.dedent("""
    import sys, json
    import numpy as np, jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint import ckpt
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_config
    from repro.data.synthetic import SyntheticLoader
    from repro.models.registry import build_model
    from repro.train import optimizer as opt
    from repro.train.loop import Trainer
    from repro.distributed.sharding import params_shardings

    ckpt_dir = sys.argv[1]
    assert len(jax.devices()) == 8
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_config("granite-3-2b", reduced=True)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=8, warmup_steps=2,
                       checkpoint_every=100, checkpoint_dir=ckpt_dir)

    class FixedLoader(SyntheticLoader):
        def batch_at(self, step):
            return super().batch_at(0)

    tr = Trainer(model, tcfg, mesh=mesh, loader=FixedLoader(cfg, 4, 32),
                 log=lambda s: None)
    params, opt_state, step0 = tr.resume_or_init()
    assert step0 == 4, step0
    # every leaf now lives on the 8-device mesh
    leaf = jax.tree.leaves(params)[0]
    assert len(leaf.sharding.device_set) == 8
    p2, o2, hist = tr.run(6, start=(params, opt_state, step0))
    print("LOSS", hist[-1]["loss"])
""")


@pytest.mark.slow
def test_checkpoint_reshards_onto_bigger_mesh(tmp_path):
    cfg = get_config("granite-3-2b", reduced=True)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=8, warmup_steps=2,
                       checkpoint_every=4, checkpoint_dir=str(tmp_path))

    class FixedLoader(SyntheticLoader):
        def batch_at(self, step):
            return super().batch_at(0)

    tr = Trainer(model, tcfg, loader=FixedLoader(cfg, 4, 32),
                 log=lambda s: None)
    params, opt_state, hist = tr.run(4)
    assert ckpt.latest_step(tmp_path) == 4
    ref_loss5 = None  # continue on 1 device for the reference
    _, _, hist2 = tr.run(6, start=(params, opt_state, 4))
    ref_loss5 = hist2[-1]["loss"]

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _CHILD, str(tmp_path)], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    loss8 = float(r.stdout.strip().split("LOSS")[-1])
    # same data, same math → same loss trajectory across topologies
    np.testing.assert_allclose(loss8, ref_loss5, rtol=1e-3)
