"""The fused on-device loops (engine.run / engine.run_until_drained).

`run` advances a traced epoch count through one compiled ``fori_loop``
program (no per-length retrace); `run_until_drained` fuses an entire
drain-to-empty simulation — step, drain predicate, stats — into a single
``lax.while_loop`` dispatch with donated buffers.  Pinned here:

* equivalence: for every registered workload, the fused drive lands on the
  host-chunked drive's exact bits (state leaf by leaf, identical Stats) —
  the drained state is a step fixpoint, so an early while_loop exit and the
  full fixed horizon agree;
* the ``max_epochs`` bound: a never-draining workload runs exactly the
  bound, epoch counter included;
* a whole draining simulation really is ONE dispatch, bit-exact against the
  sequential oracle at the epoch the predicate fired;
* donation: the input state's buffers are consumed (is_deleted), so chained
  ``st = eng.run...(st, ...)`` rebinds never double-buffer;
* no per-length retrace: three different epoch counts, one compiled program.

The D=4 face of the same equivalence runs through the conformance
subprocess driver's ``--drain`` flag (multi-device while_loop + collectives
in the body).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.engine import EngineConfig, ParsirEngine
from repro.core.ref_engine import run_sequential
from repro.testing import assert_clean
from repro.workloads.registry import (all_workloads, conformance_spec,
                                      get_workload)


def _build(workload):
    spec = conformance_spec(workload)
    model = get_workload(workload, **spec["model_kw"])
    cfg = EngineConfig(lookahead=model.params.lookahead, **spec["engine_kw"])
    return ParsirEngine(model, cfg), spec


def _assert_states_equal(a, b, *, include_epoch, ctx=""):
    for field in a._fields:
        if field == "epoch" and not include_epoch:
            continue
        la, lb = (jax.tree.leaves(getattr(s, field)) for s in (a, b))
        assert len(la) == len(lb), (ctx, field)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"{ctx} state leaf [{field}] diverges")


@pytest.mark.parametrize("workload", all_workloads())
def test_fused_drain_equals_host_chunked(workload):
    eng, spec = _build(workload)
    n = spec["n_epochs"]
    a = eng.run(eng.init(), n)
    d0 = eng.dispatches
    b = eng.run_until_drained(eng.init(), n)
    assert eng.dispatches - d0 == 2  # init + the one fused dispatch

    assert eng.totals(a) == eng.totals(b)
    drained = eng.in_flight(b) == 0
    # a draining workload may exit the while_loop early; every leaf except
    # the epoch counter must still match (drained state is a step fixpoint).
    _assert_states_equal(a, b, include_epoch=not drained, ctx=workload)
    assert int(np.asarray(b.epoch)[0]) <= n


def test_max_epochs_bound_runs_exactly_the_bound():
    # classic PHOLD conserves its event population — the predicate never
    # fires, so the fused loop is `run` exactly, epoch counter included.
    eng, _ = _build("phold")
    a = eng.run(eng.init(), 5)
    b = eng.run_until_drained(eng.init(), 5)
    assert eng.in_flight(b) > 0
    assert int(np.asarray(b.epoch)[0]) == 5
    _assert_states_equal(a, b, include_epoch=True, ctx="phold/bound")


def test_whole_drain_simulation_is_one_dispatch_and_oracle_exact():
    # acceptance rung: finite arrival budgets + no handoffs → the network
    # empties; init-to-empty is a single XLA program launch, bit-identical
    # to the sequential oracle at the drain epoch.
    model = get_workload("wireless", n_cells=6, n_channels=2, max_calls=3,
                         handoff_p=0, lookahead=0.5, dist="dyadic")
    cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=64,
                       route_cap=512, fallback_cap=512)
    eng = ParsirEngine(model, cfg)
    st = eng.init()
    d0 = eng.dispatches
    st = eng.run_until_drained(st, 200)
    assert eng.dispatches - d0 == 1
    assert eng.in_flight(st) == 0
    epochs = int(np.asarray(st.epoch)[0])
    assert 0 < epochs < 200  # the predicate fired, not the bound
    tot = eng.totals(st)
    assert_clean(tot, context="fused drain")

    ref = run_sequential(model, epochs, cfg.epoch_len)
    assert tot["processed"] == ref.total_processed
    gobj = eng.global_object_state(st)
    for k in ref.obj_state[0]:
        want = np.stack([np.asarray(s[k]) for s in ref.obj_state])
        np.testing.assert_array_equal(gobj[k], want,
                                      err_msg=f"object state [{k}]")


def test_fused_loops_donate_their_input():
    # both on-device loops take the state by donation: after the call the
    # input handle's buffers are consumed, so a chunked inspection loop
    # (`st = eng.run(st, k)` repeatedly) never holds two live states.
    eng, _ = _build("phold")
    st0 = eng.init()
    probe = st0.cal.cnt
    st1 = eng.run(st0, 3)
    assert probe.is_deleted()
    probe = st1.cal.cnt
    st2 = eng.run_until_drained(st1, 3)
    assert probe.is_deleted()
    assert not st2.cal.cnt.is_deleted()


def test_run_compiles_once_for_any_epoch_count():
    eng, _ = _build("phold")
    st = eng.init()
    for n in (1, 2, 7):
        st = eng.run(st, n)
    assert int(np.asarray(st.epoch)[0]) == 10
    if hasattr(eng._run_sm, "_cache_size"):
        # the epoch count is a traced operand — three lengths, one program
        # (the retired implementation retraced per distinct n_epochs).
        assert eng._run_sm._cache_size() == 1


@pytest.mark.slow
@pytest.mark.parametrize("workload", all_workloads())
def test_fused_drain_conformance_multidevice(workload):
    # D=4: the while_loop body contains real collectives (a2a exchange,
    # psum'd drain predicate); the full conformance assertions run against
    # the fused drive via the harness's --drain flag.
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.testing.conformance",
           "--workload", workload, "--devices", "4",
           "--configs", "batch-a2a", "--drain"]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "CONFORMANCE PASS" in r.stdout
