"""Training substrate: optimizer math, checkpoint save/restore/resume,
supervised stepping (failure retry + straggler accounting), loss-goes-down."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.synthetic import SyntheticLoader
from repro.ft.supervisor import StepFailure, StragglerStats, SupervisedStep
from repro.models.registry import build_model
from repro.train import optimizer as opt
from repro.train.loop import Trainer


def test_adamw_reduces_quadratic():
    w = jnp.asarray([3.0, -2.0, 1.5])
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, grad_clip=10.0)
    state = opt.init(w)
    for _ in range(150):
        g = 2 * w
        w, state, m = opt.update(g, state, w, tcfg)
    assert float(jnp.sum(w * w)) < 1e-2


def test_grad_clip_caps_global_norm():
    g = {"a": jnp.full((4,), 100.0), "b": jnp.full((2,), -100.0)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    assert float(opt.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(gn) > 100


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "n": jnp.asarray(7, jnp.int32)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    got, step = ckpt.restore(tmp_path, tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    # GC kept only 2
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_4", "step_5"]


def test_checkpoint_restore_rejects_shape_mismatch(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"w": jnp.zeros((4,))})


def test_supervised_step_retries_then_raises():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        raise RuntimeError("injected device failure")

    s = SupervisedStep(flaky, max_retries=2)
    with pytest.raises(StepFailure):
        s(1)
    assert calls["n"] == 3  # initial + 2 retries


def test_straggler_detection():
    st = StragglerStats()
    for _ in range(10):
        st.update(0.1)
    assert st.slow_steps == 0
    assert st.update(1.0)  # 10x EWMA → straggler
    assert st.slow_steps == 1
    # EWMA not poisoned by the straggler
    assert st.ewma_s < 0.2


def test_trainer_end_to_end_with_resume(tmp_path):
    cfg = get_config("granite-3-2b", reduced=True)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=8, warmup_steps=2,
                       checkpoint_every=4, checkpoint_dir=str(tmp_path),
                       keep_checkpoints=2)

    class FixedLoader(SyntheticLoader):
        def batch_at(self, step):  # same batch → loss must drop monotonically
            return super().batch_at(0)

    loader = FixedLoader(cfg, 2, 32)
    tr = Trainer(model, tcfg, loader=loader, log=lambda s: None)
    params, opt_state, hist = tr.run(8)
    assert hist[-1]["loss"] < hist[0]["loss"]  # loss went down
    assert ckpt.latest_step(tmp_path) == 8

    # crash-restart: a fresh Trainer resumes from step 8 and continues
    tr2 = Trainer(model, tcfg, loader=loader, log=lambda s: None)
    p2, o2, step0 = tr2.resume_or_init()
    assert step0 == 8
    _, _, hist2 = tr2.run(10, start=(p2, o2, step0))
    assert len(hist2) == 2  # only steps 8, 9 executed


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("granite-3-2b", reduced=True)
    model = build_model(cfg)
    loader = SyntheticLoader(cfg, 4, 16)
    batch = loader.batch_at(0)
    from repro.train.step import make_train_step
    params = model.init(jax.random.key(0))

    t_full = TrainConfig(microbatch=0, warmup_steps=1)
    t_acc = TrainConfig(microbatch=2, warmup_steps=1)
    p1, _, m1 = jax.jit(make_train_step(model, t_full))(
        params, opt.init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(model, t_acc))(
        params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
