"""The pipeline decomposition contract (repro/core/pipeline):

* stage registries carry the built-ins; unknown stage names fail at
  EngineConfig *construction* time, not deep inside a trace;
* a user-registered Scheduler is selectable by name and round-trips the
  whole engine (identical results to the built-in it wraps);
* the a2a capacity validation fails fast instead of silently spilling every
  event to fallback (route_cap // D == 0 regression);
* the width-packer (batch_impl='packed'): deterministic edge cases
  (all-empty / single-row / full-width slices, zero local rows) plus the
  engine-level "same bits, different schedule" equivalence vs the dense
  rounds loop — the hypothesis round-trip properties live in
  test_property.py;
* event-batch helpers (compact_mask / concat_batches / truncate) preserve
  the valid-event multiset — the algebra `route` and `deliver` stages lean
  on (property-style over seeded random batches, no hypothesis dependency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, ParsirEngine
from repro.core.events import (EventBatch, compact, compact_mask,
                               concat_batches, truncate)
from repro.core.pipeline import (ROUTERS, SCHEDULERS, STEAL_POLICIES,
                                 Scheduler, pack_slice, register_scheduler,
                                 resolve_scheduler, unpack_slice)
from repro.core.pipeline.schedulers import (process_batch_packed,
                                            process_batch_rounds)
from repro.testing.fixtures import random_sorted_slice
from repro.workloads.registry import get_workload


# ---------------------------------------------------------------------------
# registries + construction-time validation
# ---------------------------------------------------------------------------

def test_builtin_stages_registered():
    assert {"batch", "batch-packed", "batch-model", "ltf"} <= set(SCHEDULERS)
    assert {"allgather", "a2a"} <= set(ROUTERS)
    assert {"none", "loan"} <= set(STEAL_POLICIES)


def test_stage_name_truth_sets_track_registries():
    # repro.core.pipeline.names is the jax-free single source the CLI driver
    # and the stdlib-only docs checker consume — every declared name must
    # resolve in the live registries (registries may additionally hold
    # user-registered stages, so these are subset checks), and the internal
    # batch-family scheduler names must stay out of the selectable set.
    from repro.core.pipeline import base, names
    assert base.BATCH_IMPLS is names.BATCH_IMPLS
    assert set(names.ROUTES) <= set(ROUTERS)
    assert {"allgather", "a2a"} <= set(names.ROUTES)
    assert set(names.BATCH_IMPLS) == {"rounds", "packed", "model"}
    assert set(names.BATCH_IMPLS.values()) <= set(SCHEDULERS)
    internal = set(names.BATCH_IMPLS.values()) - {"batch"}
    assert not internal & set(names.SELECTABLE_SCHEDULERS)
    for s in names.SELECTABLE_SCHEDULERS:
        assert s in SCHEDULERS, s
    for p in names.PLACEMENTS:  # every declared placement is constructible
        kw = dict(rebalance_every=4) if p == "adaptive" else {}
        EngineConfig(lookahead=0.5, placement=p, **kw)


@pytest.mark.parametrize("bad_kw", [dict(route="bogus"),
                                    dict(scheduler="bogus"),
                                    dict(batch_impl="bogus"),
                                    dict(route_cap=0),
                                    dict(n_buckets=0),
                                    dict(pack_tile=0),
                                    dict(steal=True, steal_cap=0),
                                    dict(steal=True, claim_cap=0),
                                    dict(epoch_len=0.0),
                                    dict(epoch_len=-1.0)])
def test_unknown_or_degenerate_config_fails_at_construction(bad_kw):
    with pytest.raises(ValueError):
        EngineConfig(lookahead=0.5, **bad_kw)


@pytest.mark.parametrize("la", [0.0, -2.0])
def test_nonpositive_lookahead_fails_at_construction(la):
    with pytest.raises(ValueError, match="lookahead"):
        EngineConfig(lookahead=la)


def test_a2a_route_cap_validation_fails_fast():
    # pair_cap = route_cap // D == 0 used to silently drop every event into
    # overflow; now the engine-side validation refuses the config outright.
    cfg = EngineConfig(lookahead=0.5, route="a2a", route_cap=2)
    with pytest.raises(ValueError, match="route_cap"):
        cfg.validate(n_devices=4)
    # divisible-and-large-enough passes
    EngineConfig(lookahead=0.5, route="a2a", route_cap=8).validate(4)


def test_resolve_scheduler_batch_impl_split():
    assert resolve_scheduler(EngineConfig(lookahead=0.5)).name == "batch"
    assert resolve_scheduler(
        EngineConfig(lookahead=0.5, batch_impl="model")).name == "batch-model"
    assert resolve_scheduler(
        EngineConfig(lookahead=0.5,
                     batch_impl="packed")).name == "batch-packed"
    assert resolve_scheduler(
        EngineConfig(lookahead=0.5, scheduler="ltf")).name == "ltf"


def test_model_kernel_scheduler_requires_process_batch():
    model = get_workload("cluster", n_nodes=8, n_rings=2)  # no process_batch
    cfg = EngineConfig(lookahead=0.5, batch_impl="model", n_buckets=8,
                       bucket_cap=32, route_cap=128, fallback_cap=128)
    with pytest.raises(ValueError, match="process_batch"):
        ParsirEngine(model, cfg)


def test_custom_registered_scheduler_runs_end_to_end():
    # registering a Scheduler class and selecting it by EngineConfig name is
    # the whole extension story — prove it round-trips the engine with
    # results identical to the built-in it delegates to.
    if "test-echo" not in SCHEDULERS:
        @register_scheduler("test-echo")
        class EchoScheduler(Scheduler):
            def process(self, model, cfg, obj, ts_s, seed_s, pay_s, cnt_b):
                return process_batch_rounds(model, obj, ts_s, seed_s, pay_s,
                                            cnt_b, cfg.lookahead)

    model = get_workload("phold", n_objects=16, initial_events=4,
                         state_nodes=64, realloc_fraction=0.02,
                         lookahead=0.5, dist="dyadic")
    kw = dict(lookahead=0.5, n_buckets=8, bucket_cap=64, route_cap=512,
              fallback_cap=512)
    eng_a = ParsirEngine(model, EngineConfig(**kw))
    eng_b = ParsirEngine(model, EngineConfig(scheduler="test-echo", **kw))
    tot_a = eng_a.totals(eng_a.run(eng_a.init(), 12))
    tot_b = eng_b.totals(eng_b.run(eng_b.init(), 12))
    assert tot_a == tot_b
    assert tot_a["processed"] > 0


def test_inconsistent_stage_combinations_fail_at_construction():
    # loan stealing processes through the rounds-family schedulers; pairing
    # it with another scheduler/impl must refuse (device-independently, at
    # config construction) rather than silently ignore the setting.
    for bad in (dict(steal=True, scheduler="ltf"),
                dict(steal=True, batch_impl="model")):
        with pytest.raises(ValueError, match="steal"):
            EngineConfig(lookahead=0.5, **bad)
    # ...but the width-packed impl ingests loan-augmented rows fine.
    EngineConfig(lookahead=0.5, steal=True, batch_impl="packed")
    # a non-rounds batch_impl under a non-batch scheduler would silently
    # never take effect.
    for impl in ("model", "packed"):
        with pytest.raises(ValueError, match="batch_impl"):
            EngineConfig(lookahead=0.5, scheduler="ltf", batch_impl=impl)
    # the internal registry names are not directly selectable.
    for internal in ("batch-model", "batch-packed"):
        with pytest.raises(ValueError, match="internal"):
            EngineConfig(lookahead=0.5, scheduler=internal)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @register_scheduler("batch")
        class Clash(Scheduler):  # pragma: no cover - never instantiated
            def process(self, *a):
                ...


# ---------------------------------------------------------------------------
# the width-packer (batch_impl='packed'): edge cases + engine equivalence
# ---------------------------------------------------------------------------

def _slice_of(cnts, cap, seed=0):
    ts, seed_a, pay, cnt, _ = random_sorted_slice(cnts, seed, cap)
    return (jnp.asarray(ts), jnp.asarray(seed_a), jnp.asarray(pay),
            jnp.asarray(cnt))


@pytest.mark.parametrize("cnts,cap,tile", [
    ([0, 0, 0, 0], 6, 2),          # all-empty: zero tiles, nothing live
    ([5], 5, 3),                   # single row, full depth
    ([4] * 6, 4, 4),               # full width: every slot occupied
    ([0, 7, 0, 1, 3], 7, 2),       # ragged
])
def test_pack_unpack_edge_cases(cnts, cap, tile):
    ts, seed, pay, cnt = _slice_of(cnts, cap)
    p = pack_slice(ts, seed, pay, cnt, tile)
    total = int(np.sum(cnts))
    assert int(np.asarray(p.valid).sum()) == total
    if total == 0:
        assert int(p.n_tiles) == 0
    # no tile mixes rounds (the conflict-freedom invariant).
    v = np.asarray(p.valid)
    k = np.nonzero(v)[0]
    rr = np.asarray(p.rnd)[v]
    for t in np.unique(k // p.tile):
        assert len(np.unique(rr[k // p.tile == t])) == 1
    uts, useed, upay, ucnt = unpack_slice(p, len(cnts), cap)
    np.testing.assert_array_equal(np.asarray(ucnt), np.asarray(cnt))
    np.testing.assert_array_equal(np.asarray(uts), np.asarray(ts))
    live = np.arange(cap)[None, :] < np.asarray(cnt)[:, None]
    np.testing.assert_array_equal(np.asarray(useed)[live],
                                  np.asarray(seed)[live])
    np.testing.assert_array_equal(np.asarray(upay)[live],
                                  np.asarray(pay)[live])


def _tiny_phold():
    return get_workload("phold", n_objects=16, initial_events=4,
                        state_nodes=64, realloc_fraction=0.02,
                        lookahead=0.5, dist="dyadic")


@pytest.mark.parametrize("n_rows", [0, 3])
@pytest.mark.parametrize("impl", ["rounds", "packed"])
def test_schedulers_handle_empty_and_tiny_slices(n_rows, impl):
    # n_rows == 0 is the previously-untested local-slice edge: a device that
    # currently owns no objects must process cleanly and emit nothing.
    model = _tiny_phold()
    obj = model.init_object_state(np.arange(n_rows))
    cap = 4
    ts = jnp.full((n_rows, cap), jnp.inf, jnp.float32)
    seed = jnp.zeros((n_rows, cap), jnp.uint32)
    pay = jnp.zeros((n_rows, cap), jnp.float32)
    cnt = jnp.zeros((n_rows,), jnp.int32)
    if impl == "rounds":
        obj2, flat, lv = process_batch_rounds(model, obj, ts, seed, pay, cnt,
                                              0.5)
    else:
        obj2, flat, lv = process_batch_packed(model, obj, ts, seed, pay, cnt,
                                              0.5, tile=2)
    assert int(lv) == 0
    assert int(flat.valid.sum()) == 0
    for a, b in zip(jax.tree.leaves(obj), jax.tree.leaves(obj2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("pack_tile", [1, 4, 64])
def test_packed_engine_bit_exact_vs_batch(pack_tile):
    # "same bits, different schedule": any tile width must reproduce the
    # dense rounds loop exactly — totals and final object state.
    model = _tiny_phold()
    kw = dict(lookahead=0.5, n_buckets=8, bucket_cap=64, route_cap=512,
              fallback_cap=512)
    a = ParsirEngine(model, EngineConfig(**kw))
    b = ParsirEngine(model, EngineConfig(batch_impl="packed",
                                         pack_tile=pack_tile, **kw))
    sa, sb = a.run(a.init(), 16), b.run(b.init(), 16)
    assert a.totals(sa) == b.totals(sb)
    assert a.totals(sa)["processed"] > 0
    oa, ob = a.global_object_state(sa), b.global_object_state(sb)
    for k in oa:
        np.testing.assert_array_equal(oa[k], ob[k], err_msg=k)


def test_occupancy_reports_padded_vs_packed_lanes():
    model = _tiny_phold()
    eng = ParsirEngine(model, EngineConfig(lookahead=0.5, n_buckets=8,
                                           bucket_cap=64, route_cap=512,
                                           fallback_cap=512))
    st = eng.run(eng.init(), 4)
    occ = eng.occupancy(st)
    # the dense rounds grid is never cheaper than the events present, and
    # both reduce from the same bucket counts.
    assert np.all(occ["padded_lanes"] >= occ["packed_lanes"])
    assert occ["events"].sum() == int(np.asarray(
        st.cal.cnt)[:, int(np.asarray(st.epoch)[0]) % 8].sum())


# ---------------------------------------------------------------------------
# event-batch algebra: valid-multiset preservation (property-style)
# ---------------------------------------------------------------------------

def _rand_batch(rng, n):
    return EventBatch(
        dst=jnp.asarray(rng.integers(0, 50, n), jnp.int32),
        ts=jnp.asarray(rng.integers(0, 1024, n) / 1024.0, jnp.float32),
        seed=jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32)),
        payload=jnp.asarray(rng.integers(0, 7, n), jnp.float32),
        valid=jnp.asarray(rng.random(n) < 0.6),
    )


def _multiset(b: EventBatch):
    v = np.asarray(b.valid)
    return sorted(zip(np.asarray(b.dst)[v].tolist(),
                      np.asarray(b.ts)[v].tolist(),
                      np.asarray(b.seed)[v].tolist(),
                      np.asarray(b.payload)[v].tolist()))


@pytest.mark.parametrize("trial", range(8))
def test_event_batch_algebra_preserves_valid_multiset(trial):
    # deterministic always-running counterpart of the hypothesis properties
    # in test_property.py (which skip when hypothesis isn't installed).
    rng = np.random.default_rng(100 + trial)
    a = _rand_batch(rng, int(rng.integers(1, 48)))
    b = _rand_batch(rng, int(rng.integers(1, 48)))

    # concat is multiset union
    cat = concat_batches(a, b)
    assert _multiset(cat) == sorted(_multiset(a) + _multiset(b))

    # compact_mask keeps exactly the selected sub-multiset, front-compacted
    # in stable order (the engine always selects a subset: send ⊆ valid).
    mask = jnp.asarray(rng.random(cat.capacity) < 0.5) & cat.valid
    sel = compact_mask(cat, mask)
    assert _multiset(sel) == _multiset(cat._replace(valid=cat.valid & mask))
    v = np.asarray(sel.valid)
    k = int(v.sum())
    assert np.all(v[:k]) and not np.any(v[k:])
    np.testing.assert_array_equal(np.asarray(sel.dst)[:k],
                                  np.asarray(cat.dst)[np.asarray(mask)])

    # truncate-after-compact partitions the multiset: kept + countable drops
    # — exactly how the route/fallback stages account overflow.
    c = compact(cat)
    cap = int(rng.integers(1, c.capacity + 1))
    kept, spilled = truncate(c, cap), np.asarray(c.valid)[cap:]
    total = len(_multiset(cat))
    assert len(_multiset(kept)) + int(spilled.sum()) == total
    if cap >= total:
        assert _multiset(kept) == _multiset(cat)
