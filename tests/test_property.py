"""Hypothesis property tests for the system invariants:

* calendar insert/extract conserves events and never reorders per object;
* the speculation shadow window (take_buckets / put_buckets) is a bit-exact
  restore: take ∘ damage ∘ put is the identity on the window's buckets —
  ring wrap-around included — and put never touches buckets outside it;
* the width-packer (batch_impl='packed') is an exact permutation: pack →
  unpack round-trips the (ts, seed, payload, cnt) slice bit-for-bit, the
  work list is stable by (round, row), and no vmap tile mixes rounds;
* the event-batch algebra (compact_mask / concat_batches / truncate) the
  route/deliver stages lean on preserves the valid-event multiset;
* the arena stack allocator keeps the free-region invariant and LIFO reuse;
* placement is a partition (every object owned by exactly one device);
* the loan planner never over-assigns receivers and is donor/receiver disjoint.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — property tests skipped, not collected")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import events as ev
from repro.core.calendar import (extract_sorted, insert, make_calendar,
                                 put_buckets, take_buckets)
from repro.core.pipeline.packing import pack_slice, unpack_slice
from repro.core.placement import equal_placement, weighted_placement
from repro.testing.fixtures import random_sorted_slice
from repro.core.stealing import plan_loans
from repro.phold import arena as ar

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.tuples(st.integers(0, 7),          # local obj
                          st.integers(0, 3),          # epoch
                          st.floats(0.0, 3.75, width=32),
                          st.integers(0, 2**32 - 1)),
                min_size=1, max_size=40))
def test_calendar_conserves_and_orders(events):
    cal = make_calendar(n_local=8, n_buckets=4, cap=64)
    li = jnp.asarray([e[0] for e in events], jnp.int32)
    ep = jnp.asarray([e[1] for e in events], jnp.int32)
    ts = jnp.asarray([e[1] + (e[2] % 1.0) for e in events], jnp.float32)
    seed = jnp.asarray([e[3] for e in events], jnp.uint32)
    pay = jnp.zeros((len(events),), jnp.float32)
    valid = jnp.ones((len(events),), bool)
    cal, ovf = insert(cal, li, ep, ts, seed, pay, valid)
    assert int(ovf) == 0
    assert int(cal.cnt.sum()) == len(events)

    seen = 0
    for epoch in range(4):
        cal, ts_s, seed_s, _, cnt = extract_sorted(cal, jnp.int32(epoch))
        cnt = np.asarray(cnt)
        ts_np = np.asarray(ts_s)
        for o in range(8):
            k = int(cnt[o])
            if k:
                row = ts_np[o, :k]
                assert np.all(np.diff(row) >= 0), "per-object ts order violated"
            seen += k
    assert seen == len(events)


# --------------------------------------------------------------------------
# speculation shadow windows: take_buckets / put_buckets (speculate.py's
# rollback restore) — snapshot semantics on the circular bucket ring
# --------------------------------------------------------------------------

_cal_events = st.lists(st.tuples(st.integers(0, 7),           # local obj
                                 st.integers(0, 3),           # epoch
                                 st.floats(0.0, 3.75, width=32),
                                 st.integers(0, 2**32 - 1)),  # seed
                       min_size=0, max_size=40)


def _populated_cal(events):
    cal = make_calendar(n_local=8, n_buckets=4, cap=64)
    if not events:
        return cal
    cal, ovf = insert(
        cal,
        jnp.asarray([e[0] for e in events], jnp.int32),
        jnp.asarray([e[1] for e in events], jnp.int32),
        jnp.asarray([e[1] + (e[2] % 1.0) for e in events], jnp.float32),
        jnp.asarray([e[3] for e in events], jnp.uint32),
        jnp.asarray([e[2] for e in events], jnp.float32),
        jnp.ones((len(events),), bool))
    assert int(ovf) == 0
    return cal


@given(_cal_events, st.integers(0, 11), st.integers(1, 3), _cal_events)
def test_take_put_buckets_restores_window_bit_exact(events, e0, n, extra):
    # take ∘ damage ∘ put == identity: speculative insertions into the
    # window vanish, the speculative extraction of the safe epoch
    # reappears, every slot bit-for-bit.  first_epoch ranges well past the
    # ring size so windows regularly straddle the wrap edge.
    cal = _populated_cal(events)
    shadow = take_buckets(cal, jnp.int32(e0), n)
    # the snapshot is in WINDOW order: axis w holds epoch e0 + w, wherever
    # that epoch lives on the ring.
    cnt = np.asarray(cal.cnt)
    for w in range(n):
        np.testing.assert_array_equal(np.asarray(shadow.cnt)[:, w],
                                      cnt[:, (e0 + w) % 4])
    cal2 = cal
    if extra:
        # damage: insert events at window epochs only (a capacity overflow
        # here is fine — dropped-on-overflow is just less damage to undo)
        cal2, _ = insert(
            cal2,
            jnp.asarray([e[0] for e in extra], jnp.int32),
            jnp.asarray([e0 + e[1] % n for e in extra], jnp.int32),
            jnp.asarray([e0 + (e[2] % 1.0) for e in extra], jnp.float32),
            jnp.asarray([e[3] for e in extra], jnp.uint32),
            jnp.zeros((len(extra),), jnp.float32),
            jnp.ones((len(extra),), bool))
    # ...and a speculative extraction, which clears the first window bucket
    cal2, *_ = extract_sorted(cal2, jnp.int32(e0))
    cal3 = put_buckets(cal2, jnp.int32(e0), shadow)
    for la, lb in zip(cal3, cal):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@given(_cal_events, st.integers(0, 11), st.integers(1, 2))
def test_put_buckets_leaves_untouched_buckets_alone(events, e0, n):
    # disjointness: a restore of window [e0, e0+n) must not disturb buckets
    # outside it — an insertion at epoch e0+n (a distinct ring bucket for
    # n < n_buckets) survives the rollback untouched.
    cal = _populated_cal(events)
    shadow = take_buckets(cal, jnp.int32(e0), n)
    out_ep = e0 + n
    cal2, ovf = insert(cal, jnp.asarray([0], jnp.int32),
                       jnp.asarray([out_ep], jnp.int32),
                       jnp.asarray([float(out_ep)], jnp.float32),
                       jnp.asarray([7], jnp.uint32),
                       jnp.zeros((1,), jnp.float32),
                       jnp.ones((1,), bool))
    assert int(ovf) == 0
    cal3 = put_buckets(cal2, jnp.int32(e0), shadow)
    ob = out_ep % 4
    assert int(cal3.cnt[0, ob]) == int(cal.cnt[0, ob]) + 1
    for w in range(n):
        b = (e0 + w) % 4
        for leaf3, leaf0 in zip(cal3, cal):
            np.testing.assert_array_equal(np.asarray(leaf3)[:, b],
                                          np.asarray(leaf0)[:, b])


def test_take_buckets_wraps_the_ring():
    # the deterministic wrap case: window [7, 8] on a 4-ring is buckets
    # [3, 0] — the snapshot must present them in window order regardless.
    cal = make_calendar(n_local=2, n_buckets=4, cap=8)
    cal, ovf = insert(cal, jnp.asarray([0, 1], jnp.int32),
                      jnp.asarray([7, 8], jnp.int32),
                      jnp.asarray([7.5, 8.5], jnp.float32),
                      jnp.asarray([1, 2], jnp.uint32),
                      jnp.zeros((2,), jnp.float32),
                      jnp.ones((2,), bool))
    assert int(ovf) == 0
    shadow = take_buckets(cal, jnp.int32(7), 2)
    assert int(shadow.cnt[0, 0]) == 1          # epoch 7 → window axis 0
    assert int(shadow.cnt[1, 1]) == 1          # epoch 8 → window axis 1
    assert float(shadow.ts[0, 0, 0]) == 7.5
    assert float(shadow.ts[1, 1, 0]) == 8.5


# --------------------------------------------------------------------------
# the width-packer: pack → unpack is an exact, order-preserving permutation
# --------------------------------------------------------------------------

_pack_case = st.tuples(
    st.lists(st.integers(0, 6), min_size=0, max_size=10),  # cnt per row
    st.integers(1, 12),                                    # tile width
    st.integers(0, 2**31 - 1),                             # value seed
)


def _pack_inputs(cnts, vseed, cap=6):
    ts, seed, pay, cnt, live = random_sorted_slice(cnts, vseed, cap)
    return ts, seed, pay, cnt, live, cap


@given(_pack_case)
def test_pack_unpack_roundtrips_slice_exactly(case):
    cnts, tile, vseed = case
    ts, seed, pay, cnt, live, cap = _pack_inputs(cnts, vseed)
    p = pack_slice(jnp.asarray(ts), jnp.asarray(seed), jnp.asarray(pay),
                   jnp.asarray(cnt), tile)
    uts, useed, upay, ucnt = unpack_slice(p, len(cnts), cap)
    np.testing.assert_array_equal(np.asarray(ucnt), cnt)
    # dead slots come back as the canonical layout (+inf ts), live slots
    # bit-for-bit — the whole slice, not just a multiset.
    np.testing.assert_array_equal(np.asarray(uts), ts)
    np.testing.assert_array_equal(np.asarray(useed)[live], seed[live])
    np.testing.assert_array_equal(np.asarray(upay)[live], pay[live])


@given(_pack_case)
def test_pack_preserves_multiset_and_per_object_order(case):
    cnts, tile, vseed = case
    ts, seed, pay, cnt, live, cap = _pack_inputs(cnts, vseed)
    p = pack_slice(jnp.asarray(ts), jnp.asarray(seed), jnp.asarray(pay),
                   jnp.asarray(cnt), tile)
    v = np.asarray(p.valid)
    assert int(v.sum()) == int(cnt.sum())
    rows, rnds = np.asarray(p.row)[v], np.asarray(p.rnd)[v]
    seeds = np.asarray(p.seed)[v]
    # multiset of (row, round, seed) is exactly the live slice slots.
    got = sorted(zip(rows.tolist(), rnds.tolist(), seeds.tolist()))
    r, c = np.nonzero(live)
    want = sorted(zip(r.tolist(), c.tolist(), seed[live].tolist()))
    assert got == want
    # work list is stable by (round, row) ⇒ strictly increasing key ⇒ an
    # object's rounds appear in order (intra-object causality).
    key = rnds.astype(np.int64) * (len(cnts) + 1) + rows
    assert np.all(np.diff(key) > 0)


@given(_pack_case)
def test_pack_tiles_never_mix_rounds(case):
    # the conflict-freedom invariant the scheduler's per-tile state
    # gather/scatter relies on: one round (⇒ distinct objects) per tile.
    cnts, tile, vseed = case
    ts, seed, pay, cnt, live, cap = _pack_inputs(cnts, vseed)
    p = pack_slice(jnp.asarray(ts), jnp.asarray(seed), jnp.asarray(pay),
                   jnp.asarray(cnt), tile)
    v = np.asarray(p.valid)
    k = np.nonzero(v)[0]
    assert k.size == 0 or k.max() < int(p.n_tiles) * p.tile
    rnds, rows = np.asarray(p.rnd)[v], np.asarray(p.row)[v]
    for t in np.unique(k // p.tile):
        in_tile = k // p.tile == t
        assert len(np.unique(rnds[in_tile])) == 1
        assert len(np.unique(rows[in_tile])) == in_tile.sum()


_batch_rows = st.lists(
    st.tuples(st.integers(0, 40),            # dst
              st.integers(0, 1023),          # ts grid point
              st.integers(0, 2**32 - 1),     # seed
              st.booleans(),                 # valid
              st.booleans()),                # mask
    min_size=1, max_size=48)


def _mk_batch(rows):
    return ev.EventBatch(
        dst=jnp.asarray([r[0] for r in rows], jnp.int32),
        ts=jnp.asarray([r[1] / 1024.0 for r in rows], jnp.float32),
        seed=jnp.asarray([r[2] for r in rows], jnp.uint32),
        payload=jnp.zeros((len(rows),), jnp.float32),
        valid=jnp.asarray([r[3] for r in rows]),
    )


def _valid_multiset(b):
    v = np.asarray(b.valid)
    return sorted(zip(np.asarray(b.dst)[v].tolist(),
                      np.asarray(b.ts)[v].tolist(),
                      np.asarray(b.seed)[v].tolist()))


@given(_batch_rows)
def test_compact_mask_preserves_valid_multiset(rows):
    b = _mk_batch(rows)
    mask = jnp.asarray([r[4] for r in rows]) & b.valid
    out = ev.compact_mask(b, mask)
    assert _valid_multiset(out) == _valid_multiset(
        b._replace(valid=b.valid & mask))
    v = np.asarray(out.valid)
    k = int(v.sum())
    assert np.all(v[:k]) and not np.any(v[k:])


@given(_batch_rows, _batch_rows)
def test_concat_batches_preserves_valid_multiset(rows_a, rows_b):
    a, b = _mk_batch(rows_a), _mk_batch(rows_b)
    assert _valid_multiset(ev.concat_batches(a, b)) == \
        sorted(_valid_multiset(a) + _valid_multiset(b))


@given(_batch_rows, st.integers(1, 64))
def test_truncate_partitions_valid_multiset(rows, cap):
    b = ev.compact(_mk_batch(rows))
    kept = ev.truncate(b, cap)
    n_spill = int(np.asarray(b.valid)[cap:].sum())
    assert len(_valid_multiset(kept)) + n_spill == len(_valid_multiset(b))
    if n_spill == 0:
        assert _valid_multiset(kept) == _valid_multiset(b)


@given(st.lists(st.integers(0, 63), min_size=1, max_size=16, unique=True))
def test_arena_free_then_alloc_is_lifo(idxs):
    a = ar.arena_init(64)
    idx = jnp.asarray(idxs, jnp.int32)
    a = ar.free_k(a, idx)
    assert int(a.top) == 64 - len(idxs)
    a, got = ar.alloc_k(a, len(idxs))
    assert int(a.top) == 64
    # LIFO: allocation returns exactly the freed set
    assert sorted(np.asarray(got).tolist()) == sorted(idxs)
    # numpy mirror agrees element-for-element
    addr_np, top_np = ar.arena_init_np(64)
    addr_np, top_np = ar.free_k_np(addr_np, top_np, np.asarray(idxs))
    addr_np, top_np, got_np = ar.alloc_k_np(addr_np, top_np, len(idxs))
    np.testing.assert_array_equal(np.asarray(got), got_np)


@given(st.integers(1, 512), st.integers(1, 16))
def test_equal_placement_is_partition(n_objects, n_devices):
    p = equal_placement(n_objects, n_devices)
    counts = p.counts()
    assert counts.sum() == n_objects
    assert counts.max() - counts.min() <= 1
    owners = p.owner_np(np.arange(n_objects))
    assert owners.min() >= 0 and owners.max() < n_devices
    for d in range(n_devices):
        lo, hi = p.range_of(d)
        assert np.all(owners[lo:hi] == d)


@given(st.lists(st.one_of(st.just(0.0), st.floats(0.0, 100.0)),
                min_size=4, max_size=64),
       st.integers(1, 8),
       st.integers(0, 64))
def test_weighted_placement_partitions(weights, n_devices, n_zero_prefix):
    # zeros are legal weights — including an all-zero vector and a zero
    # prefix (idle leading objects), which used to collapse every cut onto
    # an edge device.
    weights = [0.0] * min(n_zero_prefix, len(weights) - 1) \
        + weights[min(n_zero_prefix, len(weights) - 1):]
    p = weighted_placement(weights, n_devices)
    assert p.counts().sum() == len(weights)
    assert np.all(p.counts() >= 0)
    # true pad, not papered over
    assert p.n_local_max == int(p.counts().max())
    # every object owned by exactly one device
    owners = p.owner_np(np.arange(len(weights)))
    assert owners.min() >= 0 and owners.max() < n_devices
    if sum(weights) <= 0:
        # degenerate mass → equal split, never everything-on-one-device
        np.testing.assert_array_equal(
            p.boundaries, equal_placement(len(weights), n_devices).boundaries)


@given(st.lists(st.integers(0, 100), min_size=2, max_size=8),
       st.integers(1, 4))
def test_loan_plan_respects_capacity_and_roles(loads, claim_cap):
    D = len(loads)
    steal_cap = 3
    loads_j = jnp.asarray(loads, jnp.int32)
    # every device publishes loans of weight 1..3
    w = jnp.asarray(np.random.default_rng(0).integers(1, 4, (D, steal_cap)),
                    jnp.int32)
    valid = jnp.ones((D, steal_cap), bool)
    plan = plan_loans(loads_j, w, valid, claim_cap)
    assignee = np.asarray(plan.assignee)
    claimed = np.asarray(plan.claimed)
    total = sum(loads)
    target = -(-total // D)
    deficit = np.maximum(0, target - np.asarray(loads))
    for r in range(D):
        got = claimed & (assignee == r)
        assert got.sum() <= claim_cap
        if deficit[r] == 0:
            assert got.sum() == 0, "zero-deficit device received loans"


@given(st.integers(0, 2**32 - 1), st.integers(0, 31))
def test_rng_jax_numpy_bit_identical(seed, k):
    a = int(ev.fold(jnp.uint32(seed), k))
    b = int(ev.fold_np(np.uint32(seed), k))
    assert a == b
    assert float(ev.dyadic10(jnp.uint32(seed))) == float(ev.dyadic10_np(np.uint32(seed)))
    assert float(ev.uniform24(jnp.uint32(seed))) == float(ev.uniform24_np(np.uint32(seed)))


@given(st.integers(0, 2**32 - 1), st.integers(0, 8))
def test_dyadic_scaled_closure(bits, shift):
    # dyadic closure of the scaled draw (wireless hot cells): the value sits
    # exactly on the 1/(1024·2^shift) grid, inside [0, 2^-shift), and the
    # JAX and numpy faces agree bit-for-bit.
    a = float(ev.dyadic_scaled(jnp.uint32(bits), shift))
    b = float(ev.dyadic_scaled_np(np.uint32(bits), shift))
    assert a == b
    grid = 1024 * (1 << shift)
    scaled = a * grid
    assert scaled == int(scaled), "left the dyadic grid"
    assert 0.0 <= a < 2.0 ** -shift
    # power-of-two scaling is exact: the scaled draw is literally the base
    # draw with a shifted exponent.
    assert a == float(ev.dyadic10_np(np.uint32(bits))) * 2.0 ** -shift


@given(st.lists(st.tuples(st.integers(0, 2**32 - 1), st.integers(0, 4)),
                min_size=1, max_size=64))
def test_dyadic_scaled_partial_sums_are_exact(draws):
    # the invariant workload timestamps rely on: partial sums on the
    # 1/(1024·2^shift) grid are exactly representable below 2**(14 - shift)
    # (the window shrinks with the refinement — f32 has 24 mantissa bits and
    # the grid uses 10 + shift of them), so f32 accumulation order can't
    # introduce drift between engine and oracle inside that window.
    import fractions
    total32 = np.float32(0.0)
    exact = fractions.Fraction(0)
    for bits, shift in draws:
        d = ev.dyadic_scaled_np(np.uint32(bits), shift)
        total32 = np.float32(total32 + d)
        exact += fractions.Fraction(int(np.uint32(bits) & np.uint32(1023)),
                                    1024 * (1 << shift))
    max_shift = max(s for _, s in draws)
    assert exact < 2 ** (14 - max_shift), "strategy left the exact window"
    assert float(total32) == float(exact)


@given(st.integers(0, 2**32 - 1), st.integers(0, 6),
       st.sampled_from(["dyadic", "uniform24"]))
def test_draw_scaled_jax_numpy_bit_identical(bits, shift, dist):
    # exponential is deliberately absent: log1p rounds differently in XLA
    # and numpy, which is exactly why bit-exact conformance requires the
    # dyadic (or pure power-of-two uniform24) grids.
    a = float(ev.draw_scaled(jnp.uint32(bits), dist, shift))
    b = float(ev.draw_scaled_np(np.uint32(bits), dist, shift))
    assert a == b
