"""Benchmark-driver smoke: `pdes_perf --smoke` must run every ladder rung.

Benchmark drivers rot silently — they run in subprocesses, swallow stderr
into a result dict, and nothing in the test suite imports them.  The smoke
mode (also a CI job) runs the full ladder at tiny scale and exits nonzero on
any rung error or unclean counters; here we pin that *and* the child's
fail-fast contract for unknown model parameters (which used to be the
mechanism by which `hot_o`/`hot_p` silently no-opted on phold-hotspot).
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_pdes_perf_smoke_ladder_runs(tmp_path):
    out = tmp_path / "smoke.json"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.pdes_perf", "--workload",
         "phold-hotspot", "--devices", "1", "--smoke", "--out", str(out)],
        cwd=REPO, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    results = json.loads(out.read_text())
    # the placement rungs exist and ran clean; adaptive actually rebalanced.
    for rung in ("steal_off", "placement_weighted", "placement_adaptive"):
        assert "error" not in results[rung], results[rung]
        assert results[rung]["stats"]["oob_events"] == 0
    assert results["placement_adaptive"]["stats"]["rebalances"] > 0


def test_pdes_perf_child_rejects_unknown_model_kw():
    sys.path.insert(0, REPO)
    try:
        from benchmarks import pdes_perf
    finally:
        sys.path.pop(0)
    r = pdes_perf.run_child(1, "phold-hotspot", o=16, m=2, s=64, la=0.5,
                            dist="dyadic", route="allgather", route_cap=64,
                            epochs=1, warm=0,
                            model_kw={"hot_objcts": 4})  # typo'd key
    assert "error" in r
    assert "hot_objcts" in r["error"] or "model_kw" in r["error"]


@pytest.mark.slow
def test_pdes_perf_forwards_hot_params_to_hotspot():
    # regression: hot_o/hot_p ladder overrides used to be forwarded only for
    # wname == "phold", so the hotspot ladder ran with defaults.  Behavioral
    # probe: hot_o beyond n_objects makes ~3/4 of hot emissions out-of-range,
    # so a nonzero oob_events counter proves the override reached the model
    # (with the silently-dropped defaults it stays exactly 0).
    sys.path.insert(0, REPO)
    try:
        from benchmarks import pdes_perf
    finally:
        sys.path.pop(0)
    r = pdes_perf.run_child(1, "phold-hotspot", o=16, m=4, s=64, la=0.5,
                            dist="dyadic", route="allgather", route_cap=256,
                            epochs=3, warm=0, hot_o=64, hot_p=256)
    assert "error" not in r, r
    assert r["stats"]["oob_events"] > 0, r["stats"]
