"""End-to-end behaviour: the parallel PARSIR engine must reproduce the
sequential oracle exactly — event counts, per-object ordering, and (with the
dyadic increment distribution) bit-identical object state."""
import numpy as np
import pytest

from repro.core.engine import EngineConfig, ParsirEngine
from repro.core.ref_engine import run_sequential
from repro.phold.model import Phold, PholdParams

N_EPOCHS = 24


def small_model(**kw):
    defaults = dict(n_objects=16, initial_events=4, state_nodes=64,
                    realloc_fraction=0.02, lookahead=0.5, dist="dyadic")
    defaults.update(kw)
    return Phold(PholdParams(**defaults))


def run_engine(model, n_epochs, **cfg_kw):
    defaults = dict(lookahead=model.params.lookahead, n_buckets=8,
                    bucket_cap=64, route_cap=512, fallback_cap=512)
    defaults.update(cfg_kw)
    cfg = EngineConfig(**defaults)
    eng = ParsirEngine(model, cfg)
    st = eng.init()
    st = eng.run(st, n_epochs)
    return eng, st


def assert_clean(tot):
    assert tot["cal_overflow"] == 0
    assert tot["fb_overflow"] == 0
    assert tot["route_overflow"] == 0
    assert tot["late_events"] == 0
    assert tot["lookahead_violations"] == 0


@pytest.mark.parametrize("scheduler", ["batch", "ltf"])
def test_engine_matches_sequential_oracle(scheduler):
    model = small_model()
    eng, st = run_engine(model, N_EPOCHS, scheduler=scheduler)
    tot = eng.totals(st)
    assert_clean(tot)

    ref = run_sequential(model, N_EPOCHS, eng.cfg.epoch_len)
    assert tot["processed"] == ref.total_processed

    pay = np.asarray(st.obj["payload"])
    ref_pay = np.stack([s["payload"] for s in ref.obj_state])
    np.testing.assert_array_equal(pay, ref_pay)  # bit-exact
    np.testing.assert_array_equal(np.asarray(st.obj["top"]),
                                  np.array([s["top"] for s in ref.obj_state]))
    np.testing.assert_array_equal(
        np.asarray(st.obj["addresses"]),
        np.stack([s["addresses"] for s in ref.obj_state]))


def test_event_population_is_conserved():
    # classic PHOLD: every processed event emits exactly one → population O*M.
    model = small_model(n_objects=32, initial_events=8)
    eng, st = run_engine(model, N_EPOCHS)
    assert_clean(eng.totals(st))
    assert eng.in_flight(st) == 32 * 8


def test_epoch_fraction_run():
    # paper §IV-C: PARSIR may run with epoch length a fraction of the lookahead.
    model = small_model()
    eng, st = run_engine(model, 2 * N_EPOCHS, epoch_len=0.25)
    tot = eng.totals(st)
    assert_clean(tot)
    ref = run_sequential(model, 2 * N_EPOCHS, 0.25)
    assert tot["processed"] == ref.total_processed
    pay = np.asarray(st.obj["payload"])
    ref_pay = np.stack([s["payload"] for s in ref.obj_state])
    np.testing.assert_array_equal(pay, ref_pay)


@pytest.mark.parametrize("dist", ["uniform24", "exponential"])
def test_other_increment_distributions_run_clean(dist):
    # non-dyadic dists aren't bit-comparable to numpy, but the engine must stay
    # causally clean and conserve the event population.
    model = small_model(dist=dist)
    eng, st = run_engine(model, N_EPOCHS)
    tot = eng.totals(st)
    assert_clean(tot)
    assert tot["processed"] > 0
    assert eng.in_flight(st) == 16 * 4


def test_stats_monotone_across_chunks():
    model = small_model()
    cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=64,
                       route_cap=512, fallback_cap=512)
    eng = ParsirEngine(model, cfg)
    st = eng.init()
    prev = 0
    for _ in range(4):
        st = eng.run(st, 6)
        tot = eng.totals(st)
        assert tot["processed"] >= prev
        prev = tot["processed"]
    assert_clean(eng.totals(st))


def test_skewed_routing_matches_oracle():
    # paper §IV-A non-uniform destination distribution + stealing-relevant skew
    model = small_model(n_objects=32, hot_objects=4, hot_prob=128)
    eng, st = run_engine(model, N_EPOCHS, bucket_cap=256)
    tot = eng.totals(st)
    assert_clean(tot)
    ref = run_sequential(model, N_EPOCHS, eng.cfg.epoch_len)
    assert tot["processed"] == ref.total_processed
    pay = np.asarray(st.obj["payload"])
    ref_pay = np.stack([s["payload"] for s in ref.obj_state])
    np.testing.assert_array_equal(pay, ref_pay)
    # the skew actually concentrated load on the hot objects
    per_obj = ref.processed_per_object
    assert per_obj[:4].mean() > 3 * per_obj[4:].mean()
