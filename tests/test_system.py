"""End-to-end behaviour: the parallel PARSIR engine must reproduce the
sequential oracle exactly — counters clean, processed counts equal, pending
multisets identical, and (with the dyadic increment distribution)
bit-identical object state.

The oracle-differential machinery lives in the reusable harness
(:mod:`repro.testing.conformance`); this file drives it for the PHOLD
workloads plus the PHOLD-specific invariants (population conservation,
monotone stats, skew concentration).  The full registry sweep, including
multi-device stealing/a2a runs, is in test_workloads.py.
"""
import numpy as np
import pytest

from repro.core.engine import EngineConfig, ParsirEngine
from repro.testing import assert_clean, conformance as cf
from repro.workloads.registry import get_workload

N_EPOCHS = 24

_REF_CACHE = {}


def small_model(**kw):
    defaults = dict(n_objects=16, initial_events=4, state_nodes=64,
                    realloc_fraction=0.02, lookahead=0.5, dist="dyadic")
    defaults.update(kw)
    return get_workload("phold", **defaults)


def run_engine(model, n_epochs, **cfg_kw):
    defaults = dict(lookahead=model.params.lookahead, n_buckets=8,
                    bucket_cap=64, route_cap=512, fallback_cap=512)
    defaults.update(cfg_kw)
    eng = ParsirEngine(model, EngineConfig(**defaults))
    st = eng.run(eng.init(), n_epochs)
    return eng, st


@pytest.mark.parametrize("config",
                         ["batch-allgather", "batch-a2a", "ltf",
                          "epoch-fraction"])
def test_engine_matches_sequential_oracle(config):
    # full differential check (counters, counts, pending multiset, bit-exact
    # state) via the harness, one named sweep point per case.
    report = cf.check_workload("phold", config, ref_cache=_REF_CACHE)
    assert report["totals"]["processed"] > 0


def test_event_population_is_conserved():
    # classic PHOLD: every processed event emits exactly one → population O*M.
    model = small_model(n_objects=32, initial_events=8)
    eng, st = run_engine(model, N_EPOCHS)
    assert_clean(eng.totals(st))
    assert eng.in_flight(st) == 32 * 8


@pytest.mark.parametrize("dist", ["uniform24", "exponential"])
def test_other_increment_distributions_run_clean(dist):
    # non-dyadic dists aren't bit-comparable to numpy, but the engine must stay
    # causally clean and conserve the event population.
    model = small_model(dist=dist)
    eng, st = run_engine(model, N_EPOCHS)
    tot = eng.totals(st)
    assert_clean(tot)
    assert tot["processed"] > 0
    assert eng.in_flight(st) == 16 * 4


def test_stats_monotone_across_chunks():
    model = small_model()
    cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=64,
                       route_cap=512, fallback_cap=512)
    eng = ParsirEngine(model, cfg)
    st = eng.init()
    prev = 0
    for _ in range(4):
        st = eng.run(st, 6)
        tot = eng.totals(st)
        assert tot["processed"] >= prev
        prev = tot["processed"]
    assert_clean(eng.totals(st))


def test_skewed_routing_matches_oracle():
    # paper §IV-A non-uniform destination distribution + stealing-relevant
    # skew, now a registered workload with its own conformance recipe.
    report = cf.check_workload("phold-hotspot", "batch-allgather",
                               ref_cache=_REF_CACHE)
    per_obj = report["ref"].processed_per_object
    # the skew actually concentrated load on the hot objects
    assert per_obj[:4].mean() > 3 * per_obj[4:].mean()
