"""Epidemic workload semantics the conformance sweep can't see.

The full oracle-differential sweep lives in test_workloads.py; this file
covers the model's negative paths directly:

* a **recovered patch stops emitting** — a local step on a patch with no
  exposed/infectious members returns nothing, and a whole small epidemic
  burns out and drains the engine to empty;
* **travel absorption** — travel infections landing on depleted (S = 0) or
  already-active patches are absorbed, never spawn duplicate chains;
* **population conservation** — S + E + I + R is invariant per patch, in
  the oracle and bit-exactly in the engine;
* **ring-neighbor edge wrap** — patch 0's left neighbor is n-1 and patch
  n-1's right neighbor is 0, in both the numpy and JAX index paths.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, ParsirEngine
from repro.core.ref_engine import run_sequential
from repro.workloads.epidemic import LOCAL_STEP, TRAVEL, ring_neighbor
from repro.workloads.registry import get_workload

BURNOUT_KW = dict(n_patches=6, pop=3, n_seeds=2, trans_p=64,
                  lookahead=0.5, dist="dyadic")


def _engine(model, **cfg_kw):
    kw = dict(lookahead=model.params.lookahead, n_buckets=8, bucket_cap=64,
              route_cap=512, fallback_cap=512)
    kw.update(cfg_kw)
    return ParsirEngine(model, EngineConfig(**kw))


def _patch(model, **over):
    st = model.init_object_state_np(np.arange(model.n_objects))[0]
    for k, v in over.items():
        st[k] = np.int32(v)
    return st


def test_recovered_patch_local_step_emits_nothing():
    model = get_workload("epidemic", **BURNOUT_KW)
    # everyone recovered: the progression chain must stop (absorption).
    st = _patch(model, s=0, e=0, i=0, r=3)
    out = model.process_event_np(st, np.float32(1.0), np.uint32(7),
                                 np.float32(LOCAL_STEP))
    assert out == []
    assert (int(st["s"]), int(st["e"]), int(st["i"]), int(st["r"])) \
        == (0, 0, 0, 3)


def test_travel_on_depleted_patch_is_absorbed():
    model = get_workload("epidemic", **BURNOUT_KW)
    st = _patch(model, s=0, e=0, i=0, r=3)
    out = model.process_event_np(st, np.float32(1.0), np.uint32(7),
                                 np.float32(TRAVEL))
    assert out == []                       # nobody left to infect
    assert int(st["imports"]) == 0


def test_travel_on_active_patch_seeds_but_starts_no_second_chain():
    model = get_workload("epidemic", **BURNOUT_KW)
    st = _patch(model, s=2, e=1, i=1)
    out = model.process_event_np(st, np.float32(1.0), np.uint32(7),
                                 np.float32(TRAVEL))
    assert out == []                       # chain already running
    assert int(st["imports"]) == 1 and int(st["e"]) == 2


def test_travel_on_inactive_patch_ignites_exactly_one_chain():
    model = get_workload("epidemic", **BURNOUT_KW)
    st = _patch(model)                     # fresh: S=pop, E=I=R=0
    out = model.process_event_np(st, np.float32(1.0), np.uint32(7),
                                 np.float32(TRAVEL))
    assert len(out) == 1 and float(out[0]["payload"]) == LOCAL_STEP
    assert int(out[0]["dst"]) == int(st["gid"])
    assert float(out[0]["ts"]) >= 1.0 + BURNOUT_KW["lookahead"]


def test_epidemic_burns_out_and_drains():
    # tiny patches, weak transmission: every chain eventually exhausts its
    # E+I mass and the whole event population is absorbed.
    model = get_workload("epidemic", **BURNOUT_KW)
    eng = _engine(model)
    st = eng.run(eng.init(), 192)
    tot = eng.totals(st)
    for counter in ("cal_overflow", "fb_overflow", "route_overflow",
                    "late_events", "lookahead_violations"):
        assert tot[counter] == 0, (counter, tot)
    assert eng.in_flight(st) == 0          # recovered patches stopped emitting
    obj = {k: np.asarray(v) for k, v in st.obj.items()}
    assert np.all(obj["e"] == 0) and np.all(obj["i"] == 0)
    # population conservation, per patch.
    np.testing.assert_array_equal(
        obj["s"] + obj["e"] + obj["i"] + obj["r"],
        np.full(model.n_objects, BURNOUT_KW["pop"]))
    # and the drained state matches the oracle bit-for-bit.
    ref = run_sequential(model, 192, eng.cfg.epoch_len)
    assert tot["processed"] == ref.total_processed
    assert len(ref.pending_records) == 0
    for k in ref.obj_state[0]:
        want = np.stack([np.asarray(s[k]) for s in ref.obj_state])
        np.testing.assert_array_equal(obj[k], want, err_msg=f"state [{k}]")


def test_population_is_conserved_mid_flight():
    model = get_workload("epidemic", n_patches=16, pop=12, n_seeds=3,
                         trans_p=128, lookahead=0.5, dist="dyadic")
    eng = _engine(model)
    st = eng.run(eng.init(), 24)
    obj = {k: np.asarray(v) for k, v in st.obj.items()}
    np.testing.assert_array_equal(
        obj["s"] + obj["e"] + obj["i"] + obj["r"],
        np.full(model.n_objects, 12))
    assert obj["imports"].sum() > 0        # travel actually landed somewhere


def test_ring_neighbor_edge_wrap():
    # covers repro.core.events.ring_neighbor once for BOTH ring workloads
    # (epidemic travel routing and wireless handoff routing share it).
    n = 8
    # numpy path (the oracle): scalar ints.
    assert int(ring_neighbor(np.int32(0), 0, n)) == n - 1      # left wrap
    assert int(ring_neighbor(np.int32(n - 1), 1, n)) == 0      # right wrap
    assert int(ring_neighbor(np.int32(3), 1, n)) == 4
    # JAX path (the engine): traced arrays, boolean direction.
    g = jnp.asarray([0, n - 1, 3], jnp.int32)
    right = jnp.asarray([False, True, False])
    np.testing.assert_array_equal(np.asarray(ring_neighbor(g, right, n)),
                                  [n - 1, 0, 2])
