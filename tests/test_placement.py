"""Placement unit + negative-path coverage (always-running, no hypothesis):

* ``weighted_placement`` degenerate weights — all-zero / near-zero / negative
  / non-finite mass falls back to the equal split (never piling every object
  onto one edge device) and the returned pad is the true maximum range;
* out-of-range destinations are *counted* (``stats.oob_events``, a hard
  error at the driver like overflow), never silently clamped into another
  object's calendar by the owner searchsorted + local-index clip;
* the adaptive rebalancer's replicated boundary computation keeps every
  feasibility invariant (monotone, range <= pad, shift <= cap) under
  arbitrary measured loads;
* the padded per-device layout: a non-divisible object count over 4 devices
  still reproduces the oracle bit-exactly (subprocess, like every multi-
  device test).
"""
import os
import subprocess
import sys
from typing import Any

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import EmittedEvents, SimModel
from repro.core.engine import EngineConfig, ParsirEngine
from repro.core.pipeline.rebalance import _quantile_boundaries
from repro.core.placement import equal_placement, weighted_placement


# ---------------------------------------------------------------------------
# weighted_placement: degenerate weights
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("weights", [
    [0.0] * 8,                      # zero mass: every cut used to land at 0
    [1e-18] * 8,                    # near-zero mass (quantile underflow)
    [0.0, 0.0, 0.0, 1e-300],        # denormal tail
    [np.nan, 1.0, 1.0, 1.0],        # non-finite
    [np.inf, 1.0, 1.0, 1.0],
    [-1.0, 2.0, 2.0, 2.0],          # negative weights are meaningless
])
def test_weighted_placement_degenerate_falls_back_to_equal(weights):
    for D in (1, 2, 3, 4):
        p = weighted_placement(weights, D)
        q = equal_placement(len(weights), D)
        np.testing.assert_array_equal(p.boundaries, q.boundaries)
        assert p.n_local_max == q.n_local_max


def test_weighted_placement_zero_prefix_and_true_pad():
    # leading idle objects: cuts ride the mass, ranges stay a partition.
    p = weighted_placement([0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0], 4)
    assert p.counts().sum() == 8
    assert np.all(p.counts() >= 0)
    owners = p.owner_np(np.arange(8))
    assert owners.min() >= 0 and owners.max() < 4
    # the true pad is reported, not max(pad, 1)-papered
    assert p.n_local_max == int(p.counts().max())
    # the 4 heavy objects split one per device
    np.testing.assert_array_equal(owners[4:], [0, 1, 2, 3])


def test_weighted_placement_skew_shrinks_hot_range():
    w = np.ones(16)
    w[:4] = 16.0                    # hot head
    p = weighted_placement(w, 4)
    counts = p.counts()
    assert counts.sum() == 16
    assert counts[0] < counts[-1]   # hot device owns fewer objects
    assert p.n_local_max == int(counts.max())


# ---------------------------------------------------------------------------
# out-of-range destinations: counted, not clamped
# ---------------------------------------------------------------------------

class OobModel(SimModel):
    """Every object's events hop to the next object; odd objects instead
    emit an out-of-range destination (beyond n_objects, or negative)."""

    max_out = 1

    def __init__(self, n_objects=8, lookahead=0.5, negative=False):
        self._n, self.lookahead, self.negative = n_objects, lookahead, negative

    @property
    def n_objects(self):
        return self._n

    def init_object_state(self, global_ids):
        return {"gid": jnp.asarray(np.asarray(global_ids), jnp.int32)}

    def initial_events(self):
        o = np.arange(self._n, dtype=np.int32)
        return {"dst": o, "ts": np.full(self._n, 0.75, np.float32),
                "seed": o.astype(np.uint32),
                "payload": np.zeros(self._n, np.float32)}

    def process_event(self, state, ts, seed, payload):
        gid = state["gid"]
        bad = jnp.where(self.negative, jnp.int32(-3), jnp.int32(self._n + 2))
        dst = jnp.where(gid % 2 == 0, (gid + 2) % self._n, bad)
        out = EmittedEvents(dst=dst[None],
                            ts=(ts + jnp.float32(self.lookahead + 0.25))[None],
                            seed=(seed + jnp.uint32(1))[None],
                            payload=payload[None],
                            valid=jnp.ones((1,), bool))
        return state, out


@pytest.mark.parametrize("negative", [False, True])
def test_out_of_range_dst_is_counted_and_dropped(negative):
    model = OobModel(negative=negative)
    cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=16,
                       route_cap=64, fallback_cap=64)
    eng = ParsirEngine(model, cfg)
    st = eng.run(eng.init(), 12)
    tot = eng.totals(st)
    # every odd object's event chain dies with a *counted* oob emission
    assert tot["oob_events"] > 0
    # and nothing was mis-delivered: the surviving even chains are intact
    # (population = n/2 even starters) and no other counter tripped.
    assert eng.in_flight(st) == model.n_objects // 2
    for counter in ("cal_overflow", "fb_overflow", "route_overflow",
                    "late_events", "lookahead_violations"):
        assert tot[counter] == 0, (counter, tot)


def test_oob_initial_events_counted_at_ingest():
    class BadInit(OobModel):
        def initial_events(self):
            ev = super().initial_events()
            ev["dst"] = ev["dst"].copy()
            ev["dst"][0] = self._n + 7        # corrupt bootstrap event
            return ev

    eng = ParsirEngine(BadInit(), EngineConfig(
        lookahead=0.5, n_buckets=8, bucket_cap=16, route_cap=64,
        fallback_cap=64))
    st = eng.init()
    assert eng.totals(st)["oob_events"] == 1
    assert eng.in_flight(st) == 7             # the corrupt event never lands


_DELIVER_OOB_CHILD = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.calendar import make_calendar, make_fallback
from repro.core.engine import AXIS, EngineConfig, _shard_map
from repro.core.events import EventBatch
from repro.core.pipeline.base import resolve_router
from repro.core.pipeline.deliver import deliver
from repro.core.placement import equal_placement

D, O = 4, 16
cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=16, route_cap=64,
                   fallback_cap=16, route="a2a")
pl = equal_placement(O, D)
router = resolve_router("a2a")
pair_cap = cfg.route_cap // D

# hand-crafted per-device a2a route buffers [D, D * pair_cap]: device 0
# writes one corrupt dst (O + 5) into its peer-2 sub-buffer, so after the
# all_to_all it arrives ONLY on device 2 — a per-device-distinct batch.
dst = np.zeros((D, D * pair_cap), np.int32)
ts = np.full((D, D * pair_cap), np.inf, np.float32)
seed = np.zeros((D, D * pair_cap), np.uint32)
pay = np.zeros((D, D * pair_cap), np.float32)
valid = np.zeros((D, D * pair_cap), bool)
slot = 2 * pair_cap
dst[0, slot], ts[0, slot], valid[0, slot] = O + 5, 1.25, True

mesh = Mesh(np.array(jax.devices()[:D]), (AXIS,))
M = pl.n_local_max
cal = make_calendar(D * M, cfg.n_buckets, cfg.bucket_cap)
fb = make_fallback(D * cfg.fallback_cap)
buf = EventBatch(dst=jnp.asarray(dst.reshape(-1)),
                 ts=jnp.asarray(ts.reshape(-1)),
                 seed=jnp.asarray(seed.reshape(-1)),
                 payload=jnp.asarray(pay.reshape(-1)),
                 valid=jnp.asarray(valid.reshape(-1)))

def f(cal, fb, buf):
    dev = jax.lax.axis_index(AXIS)
    routed = router.exchange(buf, pl, cfg)
    cal, fb, cal_ovf, fb_ovf, late, n_oob = deliver(
        cal, fb, routed, jnp.int32(0), dev, pl, cfg, init=False,
        replicated=router.replicated)
    return n_oob[None]

spec = P(AXIS)
per_dev = jax.jit(_shard_map(f, mesh, (spec, spec, spec), spec))(cal, fb, buf)
per_dev = np.asarray(per_dev)
# the count lands on the device the corrupt event was routed TO — with the
# retired device-0-only reduction this was [0, 0, 0, 0].
assert per_dev.tolist() == [0, 0, 1, 0], per_dev
print("DELIVER_OOB_OK")
"""


@pytest.mark.slow
def test_a2a_deliver_counts_oob_on_the_receiving_device():
    # negative path of the replication-aware oob reduction: a corrupt dst
    # injected through the real a2a exchange must be counted on the device
    # it lands on (deliver once counted oob only on device 0, undercounting
    # every a2a slice received by devices 1..D-1).
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _DELIVER_OOB_CHILD], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "DELIVER_OOB_OK" in r.stdout


# ---------------------------------------------------------------------------
# adaptive boundary recomputation: feasibility invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("trial", range(10))
def test_quantile_boundaries_feasible(trial):
    rng = np.random.default_rng(trial)
    D = int(rng.integers(2, 7))
    O = int(rng.integers(D, 65))
    eq = equal_placement(O, D)
    M = min(O, int(np.ceil(O / D * 2.0)))
    shift_cap = int(rng.integers(1, 9))
    bounds = jnp.asarray(eq.boundaries, jnp.int32)
    load = jnp.asarray(rng.integers(0, 50, O), jnp.int32)
    nb = np.asarray(_quantile_boundaries(load, bounds, D, M, O,
                                         jnp.int32(shift_cap)))
    assert nb[0] == 0 and nb[-1] == O
    assert np.all(np.diff(nb) >= 0), nb
    assert np.all(np.diff(nb) <= M), (nb, M)
    assert np.all(np.abs(nb[1:-1] - np.asarray(eq.boundaries)[1:-1])
                  <= shift_cap)
    # zero load carries no signal: boundaries stay put
    nb0 = np.asarray(_quantile_boundaries(jnp.zeros(O, jnp.int32), bounds,
                                          D, M, O, jnp.int32(shift_cap)))
    np.testing.assert_array_equal(nb0, np.asarray(bounds))


# ---------------------------------------------------------------------------
# padded layout: non-divisible object counts (subprocess, 4 devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_non_divisible_objects_conform_multidevice():
    # 18 objects over 4 devices → ranges 4/5/4/5 with pad rows; the padded
    # layout must still reproduce the oracle bit-exactly, stealing included.
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = (
        "import numpy as np, jax\n"
        "from jax.sharding import Mesh\n"
        "from repro.core.engine import AXIS\n"
        "from repro.testing import conformance as cf\n"
        "mesh = Mesh(np.array(jax.devices()[:4]), (AXIS,))\n"
        "for config in ('batch-allgather', 'steal-a2a', 'adaptive'):\n"
        "    r = cf.check_workload('phold', config, mesh=mesh,\n"
        "                          model_overrides={'n_objects': 18})\n"
        "    print('OK', config, r['totals']['processed'])\n"
    )
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert r.stdout.count("OK") == 3
