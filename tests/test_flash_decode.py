"""Flash-decoding (sequence-parallel decode attention) correctness:
sp == gather on a real multi-device mesh (subprocess, 8 host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import get_config
    from repro.models.registry import build_model
    from repro.data.synthetic import make_batch

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    base = get_config("granite-3-2b", reduced=True)
    base = dataclasses.replace(base, n_heads=4, n_kv_heads=4, head_dim=16)
    model0 = build_model(base)
    params = model0.init(jax.random.key(0))
    batch = make_batch(base, 2, 8)

    outs = {}
    for mode in ("gather", "sp"):
        cfg = dataclasses.replace(base, decode_attn=mode)
        model = build_model(cfg)
        caches = model.init_cache(2, max_len=16, dtype=jnp.float32)
        with mesh:
            _, caches = jax.jit(model.prefill)(
                params, {"tokens": batch["tokens"][:, :4]}, caches)
            logits, _ = jax.jit(model.decode_step)(
                params, batch["tokens"][:, 4:5], caches, jnp.int32(4))
        outs[mode] = np.asarray(logits)
    err = float(np.max(np.abs(outs["gather"] - outs["sp"])))
    assert err < 1e-4, err
    print("PASS", err)
""")


@pytest.mark.slow
def test_sp_decode_matches_gather_on_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "PASS" in r.stdout
