"""The roofline counters are load-bearing for §Roofline — test them.

* jaxpr_flops: exact on dots, scan trip counts multiplied, remat recompute
  counted;
* collective_bytes: exact while-trip scaling on a known scanned TP program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import jaxpr_flops, model_flops_for


def test_jaxpr_flops_exact_on_dot():
    f = lambda a, b: a @ b
    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((64, 128), jnp.float32),
                           jax.ShapeDtypeStruct((128, 32), jnp.float32))
    assert jaxpr_flops(jx) == 2 * 64 * 128 * 32


def test_jaxpr_flops_multiplies_scan_trips():
    def f(x, w):
        def body(c, wi):
            return c @ wi, ()
        c, _ = jax.lax.scan(body, x, w)
        return c
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    jx = jax.make_jaxpr(f)(x, w)
    assert jaxpr_flops(jx) == 5 * 2 * 8 * 16 * 16


def test_jaxpr_flops_counts_remat_recompute():
    def mk(remat):
        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), ()
            b = jax.checkpoint(body) if remat else body
            c, _ = jax.lax.scan(b, x, w)
            return jnp.sum(c)
        return jax.make_jaxpr(jax.grad(f))(
            jax.ShapeDtypeStruct((6, 32, 32), jnp.float32),
            jax.ShapeDtypeStruct((8, 32), jnp.float32))
    assert jaxpr_flops(mk(True)) > jaxpr_flops(mk(False))


def test_jaxpr_flops_dot_with_batch_dims():
    f = lambda a, b: jnp.einsum("bij,bjk->bik", a, b)
    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                           jax.ShapeDtypeStruct((4, 16, 32), jnp.float32))
    assert jaxpr_flops(jx) == 4 * 2 * 8 * 16 * 32


def test_model_flops_6nd_and_2nd():
    t = model_flops_for("granite-3-2b", "train_4k")
    d = model_flops_for("granite-3-2b", "decode_32k")
    from repro.configs.registry import get_config
    n = get_config("granite-3-2b").param_count()
    assert t == pytest.approx(6.0 * n * 4096 * 256)
    assert d == pytest.approx(2.0 * n * 128)


def test_collective_parser_on_known_program():
    """Compile a scanned TP matmul on 8 host devices (subprocess-free: this
    test only runs when the process already has 1 device → use 1x1 mesh and
    assert zero collectives; the 8-device exact-scaling case is covered by
    the validation run recorded in EXPERIMENTS §Roofline)."""
    import os
    before = os.environ.get("XLA_FLAGS")
    from repro.launch.dryrun import collective_bytes
    # the dryrun module sets XLA_FLAGS for its own process; don't leak it
    # into this test process's children.
    if before is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = before
    f = jax.jit(lambda a, b: a @ b)
    comp = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32),
                   jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    out = collective_bytes(comp.as_text())
    assert all(v["count"] == 0 for v in out.values())
