"""Unit tests for the calendar multi-queue (ring reuse, conflict-free insert,
sorted extraction, fallback list)."""
import jax.numpy as jnp
import numpy as np

from repro.core import calendar as cal_mod
from repro.core.calendar import (Fallback, extract_sorted, fallback_put,
                                 insert, make_calendar, make_fallback)
from repro.core.events import EventBatch, empty_batch


def _flat_events(local_idx, epoch, ts, seed):
    k = len(local_idx)
    return (jnp.asarray(local_idx, jnp.int32), jnp.asarray(epoch, jnp.int32),
            jnp.asarray(ts, jnp.float32), jnp.asarray(seed, jnp.uint32),
            jnp.zeros((k,), jnp.float32), jnp.ones((k,), bool))


def test_insert_then_extract_sorted():
    cal = make_calendar(n_local=4, n_buckets=4, cap=8)
    li, ep, ts, seed, pay, valid = _flat_events(
        [2, 2, 2, 0], [1, 1, 1, 1], [1.9, 1.2, 1.5, 1.0], [7, 9, 8, 1])
    cal, ovf = insert(cal, li, ep, ts, seed, pay, valid)
    assert int(ovf) == 0
    assert int(cal.cnt[2, 1]) == 3 and int(cal.cnt[0, 1]) == 1

    cal, ts_s, seed_s, pay_s, cnt = extract_sorted(cal, jnp.int32(1))
    np.testing.assert_allclose(np.asarray(ts_s[2, :3]), [1.2, 1.5, 1.9])
    np.testing.assert_array_equal(np.asarray(seed_s[2, :3]), [9, 8, 7])
    assert int(cnt[2]) == 3
    # bucket cleared for ring reuse
    assert int(cal.cnt[2, 1]) == 0


def test_insert_same_ts_orders_by_seed():
    cal = make_calendar(n_local=1, n_buckets=2, cap=8)
    li, ep, ts, seed, pay, valid = _flat_events(
        [0, 0, 0], [0, 0, 0], [1.0, 1.0, 1.0], [30, 10, 20])
    cal, _ = insert(cal, li, ep, ts, seed, pay, valid)
    _, ts_s, seed_s, _, cnt = extract_sorted(cal, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(seed_s[0, :3]), [10, 20, 30])


def test_bucket_overflow_is_counted_not_silent():
    cal = make_calendar(n_local=1, n_buckets=2, cap=2)
    li, ep, ts, seed, pay, valid = _flat_events(
        [0, 0, 0, 0], [0, 0, 0, 0], [1.0, 2.0, 3.0, 4.0], [1, 2, 3, 4])
    cal, ovf = insert(cal, li, ep, ts, seed, pay, valid)
    assert int(ovf) == 2
    assert int(cal.cnt[0, 0]) == 2


def test_ring_reuse_across_epochs():
    N, cap = 4, 4
    cal = make_calendar(n_local=1, n_buckets=N, cap=cap)
    # epoch 0 and epoch N land in the same bucket — but only after 0 is drained.
    li, ep, ts, seed, pay, valid = _flat_events([0], [0], [0.5], [1])
    cal, _ = insert(cal, li, ep, ts, seed, pay, valid)
    cal, ts_s, _, _, cnt = extract_sorted(cal, jnp.int32(0))
    assert int(cnt[0]) == 1
    li, ep, ts, seed, pay, valid = _flat_events([0], [N], [float(N) + 0.5], [2])
    cal, ovf = insert(cal, li, ep, ts, seed, pay, valid)
    assert int(ovf) == 0
    cal, ts_s, seed_s, _, cnt = extract_sorted(cal, jnp.int32(N))
    assert int(cnt[0]) == 1 and float(ts_s[0, 0]) == N + 0.5


def test_invalid_events_are_ignored():
    cal = make_calendar(n_local=2, n_buckets=2, cap=4)
    li = jnp.asarray([0, 1], jnp.int32)
    ep = jnp.asarray([0, 0], jnp.int32)
    ts = jnp.asarray([1.0, 1.0], jnp.float32)
    seed = jnp.asarray([1, 2], jnp.uint32)
    pay = jnp.zeros((2,), jnp.float32)
    valid = jnp.asarray([True, False])
    cal, ovf = insert(cal, li, ep, ts, seed, pay, valid)
    assert int(cal.cnt.sum()) == 1 and int(ovf) == 0


def test_fallback_put_compacts_and_counts_overflow():
    fb = make_fallback(4)
    new = empty_batch(6)
    new = EventBatch(
        dst=jnp.arange(6, dtype=jnp.int32),
        ts=jnp.full((6,), 2.0, jnp.float32),
        seed=jnp.arange(6, dtype=jnp.uint32),
        payload=jnp.zeros((6,), jnp.float32),
        valid=jnp.asarray([True, False, True, True, True, True]),
    )
    fb2, ovf = fallback_put(fb, new)
    assert int(jnp.sum(fb2.events.valid)) == 4
    assert int(ovf) == 1  # 5 valid events, capacity 4
    # stable order: dst 0,2,3,4 kept
    np.testing.assert_array_equal(np.asarray(fb2.events.dst[:4]), [0, 2, 3, 4])
