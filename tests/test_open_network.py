"""Open-network event flow: multi-emission (max_out = 2) and absorption.

The open-queueing workload is the end-to-end proof of the generalized
emission contract; its full oracle-differential sweep lives in
test_workloads.py.  This file covers the semantics the sweep can't see:

* absorption actually *drains* — with a per-source job budget the whole
  network empties (`in_flight` → 0) and the flow-conservation ledger
  (sources → forks ×2 → sinks) balances exactly;
* `max_out > 1` traffic overflows capacities *accountably* (route_overflow /
  fb_overflow counters, never silent loss);
* the oracle-side normalization (`as_emitted`) of the variable-arity numpy
  contract: single dict, list, empty, and `valid: False` entries.
"""
import numpy as np
import pytest

from repro.core import EngineConfig, ParsirEngine
from repro.core.ref_engine import as_emitted, run_sequential
from repro.testing import assert_clean
from repro.workloads.registry import get_workload

DRAIN_KW = dict(n_sources=2, n_stage1=2, n_forks=2, n_stage2=2, n_sinks=2,
                lookahead=0.5, dist="dyadic", max_jobs=2)


def _engine(model, **cfg_kw):
    kw = dict(lookahead=model.params.lookahead, n_buckets=8, bucket_cap=64,
              route_cap=512, fallback_cap=512)
    kw.update(cfg_kw)
    return ParsirEngine(model, EngineConfig(**kw))


def test_absorbing_network_drains_to_empty():
    # drive with the fused loop: no guessed epoch horizon, one XLA dispatch.
    model = get_workload("open-queueing", **DRAIN_KW)
    eng = _engine(model)
    st = eng.run_until_drained(eng.init(), 64)
    tot = eng.totals(st)
    assert_clean(tot)

    # every event was absorbed: nothing in calendar or fallback — and the
    # while_loop exited on the drain predicate, not the epoch bound.
    assert eng.in_flight(st) == 0
    assert int(np.asarray(st.epoch)[0]) < 64

    # flow conservation: S sources × max_jobs jobs, each forked into 2 —
    # firings(4) + stage1(4) + fork(4) + stage2(8) + sink(8).
    S, J = DRAIN_KW["n_sources"], DRAIN_KW["max_jobs"]
    jobs = S * J
    assert tot["processed"] == S * J + jobs + jobs + 2 * jobs + 2 * jobs

    # per-role ledgers in the final object state.
    obj = {k: np.asarray(v) for k, v in st.obj.items()}
    kind = obj["kind"]
    assert obj["count"][kind == 0].sum() == S * J         # source firings
    assert obj["count"][kind == 2].sum() == jobs          # fork passes
    assert obj["count"][kind == 4].sum() == 2 * jobs      # sink absorptions
    assert np.all(obj["sojourn"][kind == 4] >= 0)


def test_drained_network_matches_oracle_bit_exact():
    # drained state is a step fixpoint, so the fused loop's early exit and
    # the oracle's fixed 48-epoch horizon land on the same bits.
    model = get_workload("open-queueing", **DRAIN_KW)
    eng = _engine(model)
    st = eng.run_until_drained(eng.init(), 64)
    ref = run_sequential(model, 48, eng.cfg.epoch_len)
    assert eng.totals(st)["processed"] == ref.total_processed
    assert len(ref.pending_records) == 0
    want = {k: np.stack([np.asarray(s[k]) for s in ref.obj_state])
            for k in ref.obj_state[0]}
    for k, v in want.items():
        np.testing.assert_array_equal(np.asarray(st.obj[k]), v,
                                      err_msg=f"object state [{k}]")


def test_max_out_traffic_overflow_is_accounted():
    # an undersized route capacity against fan-out traffic must *count*
    # route overflow (events recirculate via fallback, never vanish) …
    model = get_workload("open-queueing", n_sources=4, n_stage1=4, n_forks=4,
                         n_stage2=4, n_sinks=4, lookahead=0.5, dist="dyadic")
    eng = _engine(model, route_cap=4, fallback_cap=4096)
    tot = eng.totals(eng.run(eng.init(), 16))
    assert tot["route_overflow"] > 0
    # … and an undersized fallback on top of that counts fb overflow.
    eng2 = _engine(model, route_cap=4, fallback_cap=4)
    tot2 = eng2.totals(eng2.run(eng2.init(), 16))
    assert tot2["fb_overflow"] > 0


# ---------------------------------------------------------------------------
# oracle-side emission normalization
# ---------------------------------------------------------------------------

def test_as_emitted_normalization():
    e = {"dst": 1, "ts": 2.0, "seed": 3, "payload": 0.0}
    assert as_emitted(None) == []
    assert as_emitted([]) == []
    assert as_emitted(e) == [e]                      # legacy single-dict
    assert as_emitted([e, e]) == [e, e]              # multi-emission
    assert as_emitted([dict(e, valid=False), e]) == [e]   # masked lane
    assert as_emitted(dict(e, valid=True)) == [dict(e, valid=True)]


def test_oracle_enforces_max_out():
    class TwoOutLiar:
        n_objects = 1
        max_out = 1

        def init_object_state_np(self, gids):
            return [{} for _ in gids]

        def initial_events(self):
            return {"dst": np.zeros(1, np.int32),
                    "ts": np.zeros(1, np.float32),
                    "seed": np.zeros(1, np.uint32),
                    "payload": np.zeros(1, np.float32)}

        def process_event_np(self, st, ts, seed, payload):
            e = {"dst": 0, "ts": float(ts) + 1.0, "seed": 1, "payload": 0.0}
            return [e, dict(e, seed=2)]              # 2 events > max_out=1

    with pytest.raises(ValueError, match="max_out"):
        run_sequential(TwoOutLiar(), 4, 1.0)


def test_degenerate_role_counts_rejected():
    with pytest.raises(ValueError, match="n_objects >= 5"):
        get_workload("open-queueing", n_objects=4)
    with pytest.raises(ValueError, match="n_sinks"):
        get_workload("open-queueing", n_sources=1, n_stage1=1, n_forks=1,
                     n_stage2=1, n_sinks=0)
