"""Docs/registry consistency (tier-1 face of the CI docs job).

`repro.testing.docs_check` statically cross-checks the workload registry
against the README zoo table, the golden-digest registry, and the
writing-a-workload tutorial — a registered workload must be discoverable
from all three.  Running it here keeps local `pytest -q` and the CI docs
job enforcing the identical contract.
"""
from repro.testing import docs_check


def test_readme_zoo_table_names_every_workload():
    assert docs_check.check_readme_table() == []


def test_golden_registry_covers_every_workload():
    assert docs_check.check_golden_coverage() == []


def test_writing_a_workload_tutorial_is_complete():
    assert docs_check.check_tutorial() == []


def test_cli_exit_status_counts_problems(tmp_path):
    # a repo root with an empty README and no docs/ must fail loudly, with
    # one problem per missing artifact, not crash.
    (tmp_path / "README.md").write_text("# nothing here\n")
    problems = docs_check.check_readme_table(str(tmp_path)) \
        + docs_check.check_tutorial(str(tmp_path))
    assert len(problems) >= len(docs_check.all_workloads()) + 1
    assert any("writing-a-workload" in p for p in problems)
