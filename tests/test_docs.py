"""Docs/registry consistency (tier-1 face of the CI docs job).

`repro.testing.docs_check` statically cross-checks the workload registry
against the README zoo table, the golden-digest registry, and the
writing-a-workload tutorial — a registered workload must be discoverable
from all three.  Running it here keeps local `pytest -q` and the CI docs
job enforcing the identical contract.
"""
from repro.testing import docs_check


def test_readme_zoo_table_names_every_workload():
    assert docs_check.check_readme_table() == []


def test_golden_registry_covers_every_workload():
    assert docs_check.check_golden_coverage() == []


def test_writing_a_workload_tutorial_is_complete():
    assert docs_check.check_tutorial() == []


def test_simulate_cli_is_registry_driven():
    assert docs_check.check_simulate_cli() == []


def test_campaign_cli_is_registry_driven():
    assert docs_check.check_campaign_cli() == []


def test_simulate_cli_check_catches_hardcoded_choices(tmp_path):
    # a driver that hardcodes a stale choices list (the exact phold-only rot
    # this check retires) must be flagged; a missing axis flag too.
    launch = tmp_path / "src" / "repro" / "launch"
    launch.mkdir(parents=True)
    names_dir = tmp_path / "src" / "repro" / "core" / "pipeline"
    names_dir.mkdir(parents=True)
    real_names = docs_check.os.path.join(
        docs_check.REPO_ROOT, "src", "repro", "core", "pipeline", "names.py")
    (names_dir / "names.py").write_text(open(real_names).read())
    (launch / "simulate.py").write_text(
        'import argparse\n'
        'ap = argparse.ArgumentParser()\n'
        'ap.add_argument("--workload", choices=["phold"])\n'
        'ap.add_argument("--route", choices=["allgather", "a2a"])\n')
    problems = docs_check.check_simulate_cli(str(tmp_path))
    # --workload: stale literal list; --route: literal but matches truth →
    # tolerated; every other required flag — including the --opt-* ones
    # derived from names.SPECULATION_KNOBS — is missing.
    assert any("--workload" in p and "sourced" in p for p in problems)
    assert not any("`--route` choices" in p for p in problems)
    missing = (len(docs_check.SIMULATE_REQUIRED_FLAGS)
               + len(docs_check._spec_flags(str(tmp_path))) - 2)
    assert sum("exposes no" in p for p in problems) == missing
    # the speculation knobs are spelled as flags and individually required:
    # a new knob in names.SPECULATION_KNOBS that never reaches the CLI is
    # exactly the drift this check exists to catch.
    assert docs_check._spec_flags(str(tmp_path)) == (
        "--opt-window", "--opt-stage-cap", "--opt-commit", "--opt-adaptive")
    assert any("exposes no `--opt-window`" in p for p in problems)
    assert any("exposes no `--opt-commit`" in p for p in problems)


def test_cli_exit_status_counts_problems(tmp_path):
    # a repo root with an empty README and no docs/ must fail loudly, with
    # one problem per missing artifact, not crash.
    (tmp_path / "README.md").write_text("# nothing here\n")
    problems = docs_check.check_readme_table(str(tmp_path)) \
        + docs_check.check_tutorial(str(tmp_path))
    assert len(problems) >= len(docs_check.all_workloads()) + 1
    assert any("writing-a-workload" in p for p in problems)
