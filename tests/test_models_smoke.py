"""Per-architecture smoke tests: reduced config, one forward + one grad step
on CPU, asserting output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_archs, get_config
from repro.data.synthetic import make_batch
from repro.models.registry import build_model

B, S = 2, 32


@pytest.mark.parametrize("arch", all_archs())
def test_forward_and_grad_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, B, S, step=0)

    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    g = jax.jit(jax.grad(model.loss))(params, batch)
    leaves = jax.tree.leaves(g)
    assert leaves, f"{arch}: no grads"
    for l in leaves:
        assert np.all(np.isfinite(np.asarray(l, np.float32))), \
            f"{arch}: non-finite grad"

    # one SGD step must change the loss deterministically
    params2 = jax.tree.map(lambda p, gg: p - 1e-2 * gg.astype(p.dtype),
                           params, g)
    loss2 = jax.jit(model.loss)(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-lite-16b",
                                  "xlstm-1.3b", "zamba2-1.2b",
                                  "musicgen-medium"])
def test_prefill_then_decode_matches_teacher_forcing(arch):
    """Incremental decode must agree with the parallel (teacher-forced) pass."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    T = 12
    batch = make_batch(cfg, 1, T, step=1)

    caches = model.init_cache(1, max_len=2 * T, dtype=jnp.float32)
    if cfg.frontend == "audio":
        prompt = {"embeds": batch["embeds"][:, :T - 4],
                  "labels": batch["labels"][:, :T - 4]}
    elif cfg.frontend == "vision":
        prompt = {"tokens": batch["tokens"][:, :T - 4 - cfg.n_patches],
                  "patch_embeds": batch["patch_embeds"]}
    else:
        prompt = {"tokens": batch["tokens"][:, :T - 4]}

    logits_p, caches = jax.jit(model.prefill)(params, prompt, caches)
    assert np.all(np.isfinite(np.asarray(logits_p)))

    # decode 3 tokens one at a time
    if cfg.frontend == "audio":
        cur = T - 4
        for i in range(3):
            tok = batch["embeds"][:, cur + i:cur + i + 1]
            logits_d, caches = jax.jit(model.decode_step)(
                params, tok, caches, jnp.int32(cur + i))
            assert np.all(np.isfinite(np.asarray(logits_d)))
        return

    if cfg.frontend == "vision":
        full_T = cfg.n_patches + batch["tokens"].shape[1]
        cur = full_T - 4
        toks = batch["tokens"]
        tf_logits = None
    else:
        toks = batch["tokens"]
        cur = T - 4

    for i in range(3):
        nxt = toks[:, cur + i - (cfg.n_patches if cfg.frontend == "vision" else 0)
                   :cur + i + 1 - (cfg.n_patches if cfg.frontend == "vision" else 0)]
        if nxt.shape[1] == 0:
            break
        logits_d, caches = jax.jit(model.decode_step)(
            params, nxt, caches, jnp.int32(cur + i))
        assert np.all(np.isfinite(np.asarray(logits_d)))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "xlstm-1.3b", "zamba2-1.2b"])
def test_decode_equals_parallel_logits(arch):
    """Strong check: stepwise decode logits == teacher-forced logits."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    T = 8
    batch = make_batch(cfg, 1, T, step=2)
    toks = batch["tokens"]

    # teacher-forced full logits
    from repro.models.layers import embed, norm, unembed
    if hasattr(model, "backbone"):  # DecoderLM
        x = embed(cfg, params["embed"], toks)
        pos = jnp.arange(T, dtype=jnp.int32)[None]
        h, _ = model.backbone(params, x, pos)
        full = unembed(cfg, params["embed"], h)
    else:
        x = embed(cfg, params["embed"], toks)
        if arch == "xlstm-1.3b":
            h, _ = model._run(params, x, [None] * cfg.n_layers, decode=False)
        else:
            pos = jnp.arange(T, dtype=jnp.int32)[None]
            h, _, _ = model._run(params, x, pos, None, None, None, False)
        full = unembed(cfg, params["embed"], h)

    # stepwise
    caches = model.init_cache(1, max_len=T, dtype=jnp.float32)
    outs = []
    for i in range(T):
        logits, caches = jax.jit(model.decode_step)(
            params, toks[:, i:i + 1], caches, jnp.int32(i))
        outs.append(np.asarray(logits[:, 0]))
    step_logits = np.stack(outs, axis=1)
    np.testing.assert_allclose(step_logits, np.asarray(full), atol=2e-3,
                               rtol=2e-3)
