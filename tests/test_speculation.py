"""The bounded-optimism execution window (Time Warp lite, PR 9).

Positive path, D=1: no cross-device straggler can exist, so every window
commits — the engine must leap ``W + 1`` epochs per step, bit-exact vs the
conservative run, with ``rollbacks == 0`` and an exactly predictable
``spec_commits`` count.

Negative path, D=4 (subprocess): real a2a cross-device arrivals land inside
already-speculated windows — ``rollbacks`` MUST fire, and the conformance
contract (clean counters, processed count, pending multiset, bit-exact
dyadic state vs the oracle) must hold anyway, including through the fused
drain loop.  This is the straggler-injection test: every cross-device event
emitted while a window is open *is* a straggler by construction.

Also here: the opt_window=0 no-cost guarantee (nothing speculative is even
built — no shadow copies, byte-identical lowering), and the fail-fast
rejection of compositions whose state moves would escape the shadow copy
(stealing, adaptive placement), of a bucket ring too small for the window,
and of a dead opt_stage_cap.
"""
import math
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, ParsirEngine
from repro.workloads.registry import conformance_spec, get_workload


def _build(workload, model_kw=None, **over):
    spec = conformance_spec(workload)
    model = get_workload(workload, **dict(spec["model_kw"],
                                          **(model_kw or {})))
    kw = dict(lookahead=model.params.lookahead, **spec["engine_kw"], **over)
    return ParsirEngine(model, EngineConfig(**kw)), spec


CLEAN = ("cal_overflow", "fb_overflow", "route_overflow", "late_events",
         "lookahead_violations", "oob_events")


# -- positive path: the single-device leap -----------------------------------


@pytest.mark.parametrize("W", [1, 2, 4])
def test_single_device_windows_always_commit(W):
    # D=1: every event is local, V == 0 always — windows commit wholesale.
    # n_epochs split into ceil(n / (W+1)) windows, zero rollbacks, and the
    # drained bits indistinguishable from the conservative run.
    eng0, spec = _build("phold")
    n = spec["n_epochs"]
    s0 = eng0.run(eng0.init(), n)
    t0 = eng0.totals(s0)

    eng, _ = _build("phold", opt_window=W)
    s = eng.run(eng.init(), n)
    t = eng.totals(s)

    assert t["rollbacks"] == 0
    assert t["spec_commits"] == math.ceil(n / (W + 1))
    assert t["speculated"] > 0
    assert t["processed"] == t0["processed"]
    assert all(t[k] == 0 for k in CLEAN)
    assert int(np.asarray(s.epoch)[0]) == n    # bound-exact landing
    o0, o = eng0.global_object_state(s0), eng.global_object_state(s)
    for k in o0:
        np.testing.assert_array_equal(o[k], o0[k], err_msg=f"obj[{k}] W={W}")
    np.testing.assert_array_equal(np.asarray(s.cal.cnt),
                                  np.asarray(s0.cal.cnt))


def test_fused_drain_needs_fewer_iterations():
    # epochs-to-drain: the conservative drain runs one while-iteration per
    # epoch; the speculative drain commits whole windows per iteration
    # (iterations = spec_commits + rollbacks) and must drain the same
    # workload in strictly fewer, reaching the identical drained bits.
    eng0, _ = _build("wireless", model_kw=dict(max_calls=4))
    s0 = eng0.run_until_drained(eng0.init(), 512)
    t0 = eng0.totals(s0)
    epochs0 = int(np.asarray(s0.epoch)[0])
    assert eng0.in_flight(s0) == 0

    eng, _ = _build("wireless", model_kw=dict(max_calls=4), opt_window=2)
    s = eng.run_until_drained(eng.init(), 512)
    t = eng.totals(s)
    assert eng.in_flight(s) == 0
    iters = t["spec_commits"] + t["rollbacks"]
    assert iters < epochs0, (iters, epochs0)
    assert t["processed"] == t0["processed"]
    o0, o = eng0.global_object_state(s0), eng.global_object_state(s)
    for k in o0:
        np.testing.assert_array_equal(o[k], o0[k])


# -- opt_window=0: byte-identical, nothing speculative built -----------------


def test_opt_window_zero_builds_nothing_speculative():
    eng, spec = _build("phold")
    assert eng._spec_step is None
    # the compiled drain of a W=0 engine is deterministic and identical
    # across builds (no speculative ops can leak in), and differs from a
    # W>0 engine's (which carries the window machinery).
    st = eng.init()
    n = jnp.int32(8)
    text0 = eng._drain_sm.lower(st, n).as_text()
    eng_b, _ = _build("phold", opt_window=0)
    assert eng_b._spec_step is None
    assert eng_b._drain_sm.lower(eng_b.init(), n).as_text() == text0

    eng_w, _ = _build("phold", opt_window=2)
    assert eng_w._spec_step is not None
    assert eng_w._drain_sm.lower(eng_w.init(), n).as_text() != text0


# -- fail-fast rejection ------------------------------------------------------


def test_speculation_rejects_escaping_compositions():
    kw = dict(lookahead=0.5, n_buckets=8)
    with pytest.raises(ValueError, match="steal"):
        EngineConfig(**kw, opt_window=2, steal=True)
    with pytest.raises(ValueError, match="adaptive"):
        EngineConfig(**kw, opt_window=2, placement="adaptive",
                     rebalance_every=8)
    with pytest.raises(ValueError, match="n_buckets"):
        EngineConfig(lookahead=0.5, n_buckets=4, opt_window=3)
    with pytest.raises(ValueError, match="opt_window"):
        EngineConfig(**kw, opt_window=-1)
    with pytest.raises(ValueError, match="opt_stage_cap"):
        EngineConfig(**kw, opt_stage_cap=64)   # dead without a window
    # the staging default resolves to route_cap only when speculating
    assert EngineConfig(**kw, route_cap=512).opt_stage_cap == 0
    assert EngineConfig(**kw, route_cap=512,
                        opt_window=2).opt_stage_cap == 512


# -- negative path: stragglers roll the window back, bits survive ------------


@pytest.mark.slow
def test_multidevice_stragglers_roll_back_and_stay_exact():
    # 4 devices, a2a exchange, fused drain: cross-device arrivals into open
    # windows are stragglers by construction.  --expect-rollbacks asserts
    # the negative path actually fired (rollbacks > 0) while the full
    # oracle contract held (clean counters, processed count, pending
    # multiset, bit-exact dyadic state).
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.testing.conformance",
           "--workload", "phold", "--devices", "4",
           "--configs", "spec-a2a,spec-w2", "--drain", "--expect-rollbacks"]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "CONFORMANCE PASS" in r.stdout
