"""The bounded-optimism execution window (Time Warp lite, PR 9).

Positive path, D=1: no cross-device straggler can exist, so every window
commits — the engine must leap ``W + 1`` epochs per step, bit-exact vs the
conservative run, with ``rollbacks == 0`` and an exactly predictable
``spec_commits`` count.

Negative path, D=4 (subprocess): real a2a cross-device arrivals land inside
already-speculated windows — ``rollbacks`` MUST fire, and the conformance
contract (clean counters, processed count, pending multiset, bit-exact
dyadic state vs the oracle) must hold anyway, including through the fused
drain loop.  This is the straggler-injection test: every cross-device event
emitted while a window is open *is* a straggler by construction.

Deterministic straggler injection (PR 10): ``inject_straggler_every=n``
forces every n-th window to the abort path on ALL devices — at D=1, where
no organic straggler can exist, this is the only way the rollback/restore
branch executes inside tier-1.  The injected abort sequence is exactly
predictable on the host (commit advances W_eff+1 epochs, abort advances 1),
so the tests pin the ``spec_commits``/``rollbacks`` meters to the
fused-loop iteration count — the PR 10 meter identity: each device's
``spec_commits + rollbacks`` equals the number of windows it executed,
whatever its local verdict was.

Also here: the opt_window=0 no-cost guarantee (nothing speculative is even
built — no shadow copies, byte-identical lowering), and the fail-fast
rejection matrix — stealing composes only with the global all-or-nothing
vote (``opt_commit='global'``; a loaned batch executes on the borrower, so
a per-device verdict could commit a loan's emissions while its owner rolls
back), a bucket ring too small for the window, and the dead-knob rejections
(``opt_stage_cap``/``opt_commit``/``opt_adaptive``/``inject_straggler_every``
without a window).
"""
import math
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, ParsirEngine
from repro.workloads.registry import conformance_spec, get_workload


def _build(workload, model_kw=None, **over):
    spec = conformance_spec(workload)
    model = get_workload(workload, **dict(spec["model_kw"],
                                          **(model_kw or {})))
    kw = dict(lookahead=model.params.lookahead, **spec["engine_kw"], **over)
    return ParsirEngine(model, EngineConfig(**kw)), spec


CLEAN = ("cal_overflow", "fb_overflow", "route_overflow", "late_events",
         "lookahead_violations", "oob_events")


# -- positive path: the single-device leap -----------------------------------


@pytest.mark.parametrize("W", [1, 2, 4])
def test_single_device_windows_always_commit(W):
    # D=1: every event is local, V == 0 always — windows commit wholesale.
    # n_epochs split into ceil(n / (W+1)) windows, zero rollbacks, and the
    # drained bits indistinguishable from the conservative run.
    eng0, spec = _build("phold")
    n = spec["n_epochs"]
    s0 = eng0.run(eng0.init(), n)
    t0 = eng0.totals(s0)

    eng, _ = _build("phold", opt_window=W)
    s = eng.run(eng.init(), n)
    t = eng.totals(s)

    assert t["rollbacks"] == 0
    assert t["spec_commits"] == math.ceil(n / (W + 1))
    assert t["speculated"] > 0
    assert t["processed"] == t0["processed"]
    assert all(t[k] == 0 for k in CLEAN)
    assert int(np.asarray(s.epoch)[0]) == n    # bound-exact landing
    o0, o = eng0.global_object_state(s0), eng.global_object_state(s)
    for k in o0:
        np.testing.assert_array_equal(o[k], o0[k], err_msg=f"obj[{k}] W={W}")
    np.testing.assert_array_equal(np.asarray(s.cal.cnt),
                                  np.asarray(s0.cal.cnt))


def test_fused_drain_needs_fewer_iterations():
    # epochs-to-drain: the conservative drain runs one while-iteration per
    # epoch; the speculative drain commits whole windows per iteration
    # (iterations = spec_commits + rollbacks) and must drain the same
    # workload in strictly fewer, reaching the identical drained bits.
    eng0, _ = _build("wireless", model_kw=dict(max_calls=4))
    s0 = eng0.run_until_drained(eng0.init(), 512)
    t0 = eng0.totals(s0)
    epochs0 = int(np.asarray(s0.epoch)[0])
    assert eng0.in_flight(s0) == 0

    eng, _ = _build("wireless", model_kw=dict(max_calls=4), opt_window=2)
    s = eng.run_until_drained(eng.init(), 512)
    t = eng.totals(s)
    assert eng.in_flight(s) == 0
    iters = t["spec_commits"] + t["rollbacks"]
    assert iters < epochs0, (iters, epochs0)
    assert t["processed"] == t0["processed"]
    o0, o = eng0.global_object_state(s0), eng.global_object_state(s)
    for k in o0:
        np.testing.assert_array_equal(o[k], o0[k])


# -- opt_window=0: byte-identical, nothing speculative built -----------------


def test_opt_window_zero_builds_nothing_speculative():
    eng, spec = _build("phold")
    assert eng._spec_step is None
    # the compiled drain of a W=0 engine is deterministic and identical
    # across builds (no speculative ops can leak in), and differs from a
    # W>0 engine's (which carries the window machinery).
    st = eng.init()
    n = jnp.int32(8)
    text0 = eng._drain_sm.lower(st, n).as_text()
    eng_b, _ = _build("phold", opt_window=0)
    assert eng_b._spec_step is None
    assert eng_b._drain_sm.lower(eng_b.init(), n).as_text() == text0

    eng_w, _ = _build("phold", opt_window=2)
    assert eng_w._spec_step is not None
    assert eng_w._drain_sm.lower(eng_w.init(), n).as_text() != text0


# -- fail-fast rejection ------------------------------------------------------


def test_speculation_rejects_escaping_compositions():
    kw = dict(lookahead=0.5, n_buckets=8)
    # stealing under a per-device verdict could commit a loan's emissions
    # while the loan's owner rolls back — only the global vote is sound.
    with pytest.raises(ValueError, match="global"):
        EngineConfig(**kw, opt_window=2, steal=True)
    with pytest.raises(ValueError, match="global"):
        EngineConfig(**kw, opt_window=2, steal=True, opt_commit="device")
    # ... and with the global vote it now composes (PR 10 widening).
    assert EngineConfig(**kw, opt_window=2, steal=True,
                        opt_commit="global").steal
    # adaptive placement composes under BOTH commit modes: rebalance runs
    # at the safe epoch only and the window clamps short of every firing.
    assert EngineConfig(**kw, opt_window=2, placement="adaptive",
                        rebalance_every=8).opt_window == 2
    with pytest.raises(ValueError, match="opt_commit"):
        EngineConfig(**kw, opt_window=2, opt_commit="quorum")
    with pytest.raises(ValueError, match="n_buckets"):
        EngineConfig(lookahead=0.5, n_buckets=4, opt_window=3)
    with pytest.raises(ValueError, match="opt_window"):
        EngineConfig(**kw, opt_window=-1)
    with pytest.raises(ValueError, match="inject_straggler_every"):
        EngineConfig(**kw, opt_window=2, inject_straggler_every=-1)
    # dead knobs without a window fail fast instead of silently no-opping
    with pytest.raises(ValueError, match="opt_stage_cap"):
        EngineConfig(**kw, opt_stage_cap=64)
    with pytest.raises(ValueError, match="opt_commit"):
        EngineConfig(**kw, opt_commit="global")
    with pytest.raises(ValueError, match="opt_adaptive"):
        EngineConfig(**kw, opt_adaptive=True)
    with pytest.raises(ValueError, match="inject_straggler_every"):
        EngineConfig(**kw, inject_straggler_every=2)
    # the staging default resolves to route_cap only when speculating
    assert EngineConfig(**kw, route_cap=512).opt_stage_cap == 0
    assert EngineConfig(**kw, route_cap=512,
                        opt_window=2).opt_stage_cap == 512


# -- deterministic straggler injection: the rollback branch, in tier-1 -------


def _predict_meters(chunks, W, inject):
    """Host-side twin of the engine's window walk.

    A committed window advances ``w_eff + 1`` epochs (clamped to land on the
    chunk bound exactly), an injected abort advances 1; injection fires on
    every ``inject``-th window — gated on ``w_eff > 0``, matching the engine
    (a clamped-to-safe window has nothing to abort).  The window counter
    (``spec_commits + rollbacks``) persists across chunks, exactly like the
    in-carry Stats meters it predicts.
    """
    e, cm, rb = 0, 0, 0
    for c in chunks:
        bound = e + c
        while e < bound:
            w_eff = min(W, bound - e - 1)
            if inject and (cm + rb) % inject == inject - 1 and w_eff > 0:
                rb += 1
                e += 1
            else:
                cm += 1
                e += w_eff + 1
    return cm, rb


@pytest.mark.parametrize("inject", [2, 3])
def test_injected_stragglers_roll_back_bit_exact(inject):
    # D=1 has no organic straggler, so without injection the abort/restore
    # branch never executes in tier-1.  inject_straggler_every forces every
    # n-th window down it: the shadow restore must leave the drained bits
    # identical to the conservative run, and the meters must match the
    # host-predicted window walk exactly — the deterministic harness.
    W = 2
    eng0, spec = _build("phold")
    n = spec["n_epochs"]
    s0 = eng0.run(eng0.init(), n)
    t0 = eng0.totals(s0)

    eng, _ = _build("phold", opt_window=W, inject_straggler_every=inject)
    s = eng.run(eng.init(), n)
    t = eng.totals(s)

    cm, rb = _predict_meters([n], W, inject)
    assert rb > 0, "injection never fired — the rollback branch went untested"
    assert t["rollbacks"] == rb
    assert t["spec_commits"] == cm
    assert t["speculated"] > 0
    assert t["processed"] == t0["processed"]
    assert all(t[k] == 0 for k in CLEAN)
    assert int(np.asarray(s.epoch)[0]) == n    # aborts advance 1, still exact
    o0, o = eng0.global_object_state(s0), eng.global_object_state(s)
    for k in o0:
        np.testing.assert_array_equal(o[k], o0[k],
                                      err_msg=f"obj[{k}] inject={inject}")
    np.testing.assert_array_equal(np.asarray(s.cal.cnt),
                                  np.asarray(s0.cal.cnt))


def test_meters_count_iterations_and_stay_out_of_clean():
    # The PR 10 meter identity: every window ticks exactly ONE of
    # spec_commits/rollbacks on every device — their sum IS the fused-loop
    # iteration count, monotone across dispatches, and chunk boundaries
    # (which re-clamp w_eff to each chunk's bound) are predicted by the
    # same host walk.  The meters are *activity* meters, not error
    # counters: the clean-run contract must never reject a rolled-back run.
    from repro.testing.clean import CLEAN_COUNTERS
    assert "rollbacks" not in CLEAN_COUNTERS
    assert "spec_commits" not in CLEAN_COUNTERS
    assert "speculated" not in CLEAN_COUNTERS

    W, inject = 2, 2
    eng, spec = _build("phold", opt_window=W, inject_straggler_every=inject)
    n = spec["n_epochs"]
    chunks = []
    st = eng.init()
    seen, done = 0, 0
    while done < n:
        c = min(5, n - done)
        st = eng.run(st, c)
        chunks.append(c)
        done += c
        t = eng.totals(st)
        iters = t["spec_commits"] + t["rollbacks"]
        assert iters > seen, "a dispatched chunk must add >= 1 window"
        seen = iters
    cm, rb = _predict_meters(chunks, W, inject)
    assert (t["spec_commits"], t["rollbacks"]) == (cm, rb), \
        (t["spec_commits"], t["rollbacks"], cm, rb)


# -- negative path: stragglers roll the window back, bits survive ------------


@pytest.mark.slow
def test_multidevice_stragglers_roll_back_and_stay_exact():
    # 4 devices, a2a exchange, fused drain: cross-device arrivals into open
    # windows are stragglers by construction.  --expect-rollbacks asserts
    # the negative path actually fired (rollbacks > 0) while the full
    # oracle contract held (clean counters, processed count, pending
    # multiset, bit-exact dyadic state).  The PR 10 sweep covers both
    # verdict modes (spec-w2/spec-a2a default to per-device commit,
    # spec-global pins the PR 9 atomic vote), the widened compositions
    # (spec-steal under the global vote, spec-adaptive with runtime
    # rebalancing inside the window schedule) and the deterministic
    # injection harness at real device count (spec-inject).
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.testing.conformance",
           "--workload", "phold", "--devices", "4",
           "--configs",
           "spec-a2a,spec-w2,spec-global,spec-steal,spec-adaptive,spec-inject",
           "--drain", "--expect-rollbacks", "--expect-rebalances", "1"]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1800)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "CONFORMANCE PASS" in r.stdout
