"""The replication axis (engine.init_replicated / run_replicated_drained).

`run_replicated_drained` vmaps the fused step body over a leading
replication axis R, so R independent simulations — same model, different
seeds — drain inside ONE ``lax.while_loop`` dispatch.  The drain predicate
fires when *every* replication is empty; replications that drain early are
frozen (per-leaf ``where`` on the pre-step pending mask), so each lands on
exactly the state its own independent ``run_until_drained`` would produce,
epoch counter and Stats ledger included.  Pinned here:

* equivalence: for R ∈ {1, 8}, every replication of the vmapped drain is
  leaf-exact (all EngineState fields, Stats included) against its own
  independent fused drain with the same seed;
* one dispatch for the whole stack, regardless of R;
* seed threading: different seeds produce different initial-event streams
  (and seed=0 reproduces the historical stream — pinned by test_golden);
* the Stats-ledger overflow guard fails fast on horizons whose worst case
  exceeds the counter dtype, and passes sane ones;
* the campaign layer: grid enumeration is deterministic, the digest keys
  the store, a second `run_campaign` over the same spec resumes every
  point from disk, and a changed spec lands in a fresh directory.

The D=4 face of the equivalence runs through the conformance subprocess
driver's ``--replications`` flag (vmap *inside* shard_map: the body's
collectives batch over R via their vmap rules).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.engine import EngineConfig, ParsirEngine
from repro.testing import assert_clean
from repro.workloads.registry import conformance_spec, get_workload

from test_fused_drain import _assert_states_equal


def _build(workload="wireless"):
    spec = conformance_spec(workload)
    model = get_workload(workload, **spec["model_kw"])
    cfg = EngineConfig(lookahead=model.params.lookahead, **spec["engine_kw"])
    return ParsirEngine(model, cfg), spec


@pytest.mark.parametrize("workload,replications",
                         [("wireless", 1), ("wireless", 8), ("phold", 4)])
def test_replicated_drain_leaf_exact_vs_independent(workload, replications):
    # wireless drains (finite call budgets); phold never does, so its
    # replications all ride to the max_epochs bound — both paths must be
    # leaf-exact against per-seed independent drains.
    eng, spec = _build(workload)
    n = spec["n_epochs"]
    seeds = list(range(replications))

    d0 = eng.dispatches
    st = eng.init_replicated(seeds)
    st = eng.run_replicated_drained(st, n)
    assert eng.dispatches - d0 == 2  # ingest + the ONE vmapped drain

    totals = eng.totals_replicated(st)
    in_flight = eng.in_flight_replicated(st)
    for r, seed in enumerate(seeds):
        ref = eng.run_until_drained(eng.init(seed=seed), n)
        _assert_states_equal(eng.replication(st, r), ref, include_epoch=True,
                             ctx=f"{workload} R={replications} rep {r}")
        assert totals[r] == eng.totals(ref)
        assert int(in_flight[r]) == eng.in_flight(ref)
        assert_clean(totals[r], context=f"{workload} rep {r}")


def test_replications_drain_at_their_own_epochs():
    # the freeze mask, observably: with different seeds the replications
    # drain at different epochs, and each frozen epoch counter matches the
    # independent drain exactly (no replication rides to the global max).
    # (finite call budgets + no handoffs → the network really empties)
    model = get_workload("wireless", n_cells=6, n_channels=2, max_calls=3,
                         handoff_p=0, lookahead=0.5, dist="dyadic")
    cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=64,
                       route_cap=512, fallback_cap=512)
    eng = ParsirEngine(model, cfg)
    st = eng.init_replicated(range(6))
    st = eng.run_replicated_drained(st, 200)
    assert int(eng.in_flight_replicated(st).sum()) == 0
    epochs = np.asarray(st.epoch)[:, 0]
    assert len(set(epochs.tolist())) > 1, \
        f"all replications froze at the same epoch: {epochs}"


def test_seed_threads_into_initial_events():
    eng, _ = _build("wireless")
    a = eng.model.initial_events(0)
    b = eng.model.initial_events(1)
    assert not np.array_equal(a["seed"], b["seed"]), \
        "seed=1 produced seed=0's initial-event stream"
    # seed=None means params.seed (default 0): the historical stream that
    # the golden digests pin.
    np.testing.assert_array_equal(eng.model.initial_events()["seed"],
                                  a["seed"])


def test_init_replicated_rejects_empty_seed_list():
    eng, _ = _build("wireless")
    with pytest.raises(ValueError):
        eng.init_replicated([])


def test_stats_bound_fails_fast_before_dispatch():
    # worst case is n_local_max*bucket_cap events/epoch/device; a horizon
    # whose product exceeds the ledger dtype must raise BEFORE launching
    # (silent int32 wraparound corrupted counters, not crashed).
    import jax.numpy as jnp

    from repro.core.pipeline.base import stats_dtype
    eng, _ = _build("wireless")
    cap = int(jnp.iinfo(stats_dtype()).max)
    per_epoch = eng.placement.n_local_max * eng.cfg.bucket_cap
    too_many = cap // per_epoch + 1
    with pytest.raises(ValueError, match="overflow"):
        eng.check_stats_bound(too_many)
    d0 = eng.dispatches
    with pytest.raises(ValueError, match="overflow"):
        eng.run_replicated_drained(eng.init_replicated([0]), too_many)
    assert eng.dispatches - d0 == 1  # only the ingest ran, never the drain
    eng.check_stats_bound(256)  # sane horizons pass


@pytest.mark.slow
@pytest.mark.parametrize("workload,layout",
                         [("wireless", "object"), ("phold-hotspot", "object"),
                          ("wireless", "rep_shards")])
def test_replicated_conformance_multidevice(workload, layout):
    # 4 devices × R=8, both execution layouts of the stacked drain:
    # *object*-sharded (vmap inside shard_map — the while_loop body's
    # collectives batch over R) and *replication*-sharded (--rep-shards 4:
    # the R axis splits across devices, each replication collective-free in
    # its shard — the campaign throughput layout).  Each replication is
    # checked against its own sequential oracle either way.
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.testing.conformance",
           "--workload", workload, "--devices", "4",
           "--configs", "batch-a2a", "--replications", "8"]
    if layout == "rep_shards":
        cmd += ["--rep-shards", "4"]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "CONFORMANCE PASS" in r.stdout


def test_rep_sharded_drain_matches_object_sharded_in_process():
    # the rep-sharded layout on ONE device (rep_shards=1 — degenerate but
    # exercises the 2D mesh, sharded ingest, local-pending freeze and the
    # all_gathered drain predicate) must be leaf-exact vs per-seed
    # independent drains, and must reject indivisible seed counts.
    eng, spec = _build("wireless")
    from repro.core.engine import EngineConfig as _EC, ParsirEngine as _PE
    rs = _PE(eng.model, _EC(lookahead=eng.model.params.lookahead,
                            **spec["engine_kw"]), rep_shards=1)
    n = spec["n_epochs"]
    st = rs.run_replicated_drained(rs.init_replicated([0, 1]), n)
    for r, seed in enumerate([0, 1]):
        ref = eng.run_until_drained(eng.init(seed=seed), n)
        _assert_states_equal(rs.replication(st, r), ref, include_epoch=True,
                             ctx=f"rep_shards=1 rep {r}")
    with pytest.raises(ValueError, match="devices"):
        _PE(eng.model, _EC(lookahead=eng.model.params.lookahead,
                           **spec["engine_kw"]), rep_shards=64)


# -- the campaign layer ------------------------------------------------------


def _tiny_spec(**over):
    from repro.campaign import CampaignSpec
    kw = dict(
        workload="wireless",
        seeds=(0, 1, 2),
        base_model_kw=dict(n_cells=6, n_channels=2, handoff_p=0,
                           lookahead=0.5, dist="dyadic"),
        grid={"max_calls": [2, 3]},
        engine_kw=dict(lookahead=0.5, n_buckets=8, bucket_cap=64,
                       route_cap=512, fallback_cap=512),
        devices=1,
        max_epochs=200,
    )
    kw.update(over)
    return CampaignSpec(**kw)


def test_campaign_grid_enumeration_is_deterministic():
    spec = _tiny_spec(grid={"max_calls": [2, 3], "hot_streams": [0, 1]})
    pts = spec.points()
    assert len(pts) == 4
    assert pts == spec.points()  # stable across calls
    # every point carries the base kwargs plus one grid assignment
    assert all(p["handoff_p"] == 0 for p in pts)
    assert sorted((p["max_calls"], p["hot_streams"]) for p in pts) \
        == [(2, 0), (2, 1), (3, 0), (3, 1)]
    # grid/seed/engine changes all move the digest (the store key)
    assert spec.digest() != _tiny_spec().digest()
    assert _tiny_spec().digest() != _tiny_spec(seeds=(0, 1)).digest()


def test_campaign_runs_then_resumes_from_store(tmp_path):
    from repro.campaign import ResultsStore, run_campaign
    spec = _tiny_spec()
    store = ResultsStore(tmp_path / "results")

    first = run_campaign(spec, store=store)
    assert (first["ran"], first["resumed"]) == (2, 0)
    assert first["missing"] == [] and first["unclean"] == []
    assert first["undrained"] == []
    for res in first["results"]:
        assert res["dispatches"] == 2  # ingest + ONE vmapped drain
        assert [rep["seed"] for rep in res["replications"]] == [0, 1, 2]
        assert all(rep["in_flight"] == 0 for rep in res["replications"])

    second = run_campaign(spec, store=store)
    assert (second["ran"], second["resumed"]) == (0, 2)
    # resumed results are the stored bits, not re-runs
    assert [r["replications"] for r in second["results"]] \
        == [r["replications"] for r in first["results"]]

    # a different spec gets a fresh directory — no stale-result aliasing
    other = _tiny_spec(seeds=(5, 6))
    assert store.run_dir(other) != store.run_dir(spec)
    assert store.missing(other) == [0, 1]


def test_campaign_resume_reruns_corrupt_points(tmp_path):
    # a run killed mid-write outside put()'s atomic rename (or a truncated
    # restore) leaves a zero-byte / corrupt point-<i>.json; existence-based
    # resume would count it done and hole the campaign.  Corrupt points must
    # read as missing and be re-run.
    from repro.campaign import ResultsStore, run_campaign
    spec = _tiny_spec()
    store = ResultsStore(tmp_path / "results")
    first = run_campaign(spec, store=store)
    assert store.missing(spec) == []

    store._point_path(spec, 0).write_text("")            # zero-byte
    store._point_path(spec, 1).write_text("{\"trunc")    # torn write
    assert not store.has(spec, 0) and not store.has(spec, 1)
    assert store.missing(spec) == [0, 1]

    second = run_campaign(spec, store=store)
    assert (second["ran"], second["resumed"]) == (2, 0)
    assert [r["replications"] for r in second["results"]] \
        == [r["replications"] for r in first["results"]]
    assert store.missing(spec) == []


def test_store_get_names_digest_and_index_when_absent(tmp_path):
    from repro.campaign import ResultsStore
    spec = _tiny_spec()
    store = ResultsStore(tmp_path)
    with pytest.raises(KeyError, match=f"{spec.digest()[:12]}.*point 1"):
        store.get(spec, 1)


def test_git_commit_marks_dirty_trees(tmp_path):
    import subprocess
    from repro.campaign.store import git_commit

    # outside any checkout: unknown (tmp dirs don't sit under a repo)
    assert git_commit(cwd=str(tmp_path)) == "unknown"

    repo = tmp_path / "repo"
    repo.mkdir()
    def g(*a):
        subprocess.run(["git", "-c", "user.email=t@example.com",
                        "-c", "user.name=t", *a], cwd=repo, check=True,
                       capture_output=True)
    g("init")
    g("commit", "--allow-empty", "-m", "seed")
    clean = git_commit(cwd=str(repo))
    assert len(clean) == 40 and not clean.endswith("+dirty")

    (repo / "f.txt").write_text("untracked counts as dirty too")
    assert git_commit(cwd=str(repo)) == clean + "+dirty"
    g("add", "f.txt")
    assert git_commit(cwd=str(repo)) == clean + "+dirty"   # staged, uncommitted
    g("commit", "-m", "add f")
    committed = git_commit(cwd=str(repo))
    assert committed != clean and not committed.endswith("+dirty")


def test_campaign_manifest_guards_against_digest_mismatch(tmp_path):
    from repro.campaign import ResultsStore
    spec = _tiny_spec()
    store = ResultsStore(tmp_path)
    store.write_manifest(spec)
    store.write_manifest(spec)  # idempotent
    clash = _tiny_spec(seeds=(9,))
    # simulate a hand-mangled store: same dir, different campaign
    (store.run_dir(clash)).mkdir(parents=True, exist_ok=True)
    manifest = store.run_dir(spec) / "manifest.json"
    (store.run_dir(clash) / "manifest.json").write_text(manifest.read_text())
    with pytest.raises(ValueError, match="different campaign"):
        store.write_manifest(clash)
