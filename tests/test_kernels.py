"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref.py oracles,
swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


# -- event_apply ---------------------------------------------------------------

@pytest.mark.parametrize("n,S,C", [(2, 128, 4), (4, 256, 8), (1, 512, 16),
                                   (8, 160, 5)])
def test_event_apply_matches_ref_bitexact(n, S, C):
    LANES = 6
    K, KR = max(1, S // 32), 3
    payload = jnp.asarray(RNG.random((n, LANES, S), np.float32))
    addresses = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (n, S))
    top = jnp.full((n,), S, jnp.int32)
    ts = jnp.asarray(np.sort(RNG.random((n, C)).astype(np.float32), axis=1))
    seed = jnp.asarray(RNG.integers(0, 2**32, (n, C), dtype=np.uint32))
    cnt = jnp.asarray(RNG.integers(0, C + 1, (n,), dtype=np.int32))
    kw = dict(n_objects=64, lookahead=0.5, K=K, KR=KR, dist="dyadic")
    got = ops.event_apply(payload, addresses, top, ts, seed, cnt, **kw,
                          use_pallas=True)
    want = ops.event_apply(payload, addresses, top, ts, seed, cnt, **kw,
                           use_pallas=False)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("dist", ["dyadic", "uniform24", "exponential"])
def test_event_apply_distributions(dist):
    n, LANES, S, C = 2, 6, 128, 4
    payload = jnp.asarray(RNG.random((n, LANES, S), np.float32))
    addresses = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (n, S))
    top = jnp.full((n,), S, jnp.int32)
    ts = jnp.asarray(np.sort(RNG.random((n, C)).astype(np.float32), axis=1))
    seed = jnp.asarray(RNG.integers(0, 2**32, (n, C), dtype=np.uint32))
    cnt = jnp.full((n,), C, jnp.int32)
    kw = dict(n_objects=16, lookahead=0.25, K=4, KR=2, dist=dist)
    got = ops.event_apply(payload, addresses, top, ts, seed, cnt, **kw,
                          use_pallas=True)
    want = ops.event_apply(payload, addresses, top, ts, seed, cnt, **kw,
                           use_pallas=False)
    np.testing.assert_allclose(np.asarray(got[4]), np.asarray(want[4]),
                               rtol=1e-6)  # emitted ts
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(want[3]))


# -- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (1, 4, 2, 128, 128, 64),   # GQA group 2
    (2, 8, 2, 256, 256, 64),   # GQA group 4
    (1, 2, 2, 64, 64, 32),     # MHA
    (1, 4, 1, 96, 96, 32),     # ragged (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype):
    B, Hq, Hkv, Tq, Tk, D = shape
    q = jnp.asarray(RNG.standard_normal((B, Hq, Tq, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Tk, D)), dtype)
    got = ops.mha(q, k, v, causal=True, bq=64, bk=64, use_pallas=True)
    want = ops.mha(q, k, v, causal=True, use_pallas=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    got = ops.mha(q, k, v, causal=False, bq=64, bk=64, use_pallas=True)
    want = ops.mha(q, k, v, causal=False, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# -- SSD ------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 64, 2, 32, 16), (2, 160, 4, 64, 32),
                                   (1, 96, 1, 16, 8)])
def test_ssd_matches_sequential_ref(shape):
    b, T, H, P, N = shape
    x = jnp.asarray(RNG.standard_normal((b, T, H, P)), jnp.float32) * 0.5
    dt = jnp.asarray(RNG.random((b, T, H)), jnp.float32) * 0.2
    A = -jnp.asarray(RNG.random((H,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, T, N)), jnp.float32) * 0.3
    C = jnp.asarray(RNG.standard_normal((b, T, N)), jnp.float32) * 0.3
    got = ops.ssd(x, dt, A, B, C, chunk=32, use_pallas=True)
    want = ops.ssd(x, dt, A, B, C, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_ssd_bf16():
    b, T, H, P, N = 1, 64, 2, 32, 16
    x = jnp.asarray(RNG.standard_normal((b, T, H, P)), jnp.bfloat16) * 0.5
    dt = jnp.asarray(RNG.random((b, T, H)), jnp.float32) * 0.2
    A = -jnp.asarray(RNG.random((H,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, T, N)), jnp.float32) * 0.3
    C = jnp.asarray(RNG.standard_normal((b, T, N)), jnp.float32) * 0.3
    got = ops.ssd(x, dt, A, B, C, chunk=32, use_pallas=True)
    want = ops.ssd(x, dt, A, B, C, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=5e-2)


# -- kernel-in-engine integration ------------------------------------------------

def test_engine_with_pallas_batch_impl_matches_oracle():
    from repro.core.engine import EngineConfig, ParsirEngine
    from repro.core.ref_engine import run_sequential
    from repro.phold.model import Phold, PholdParams

    p = PholdParams(n_objects=8, initial_events=4, state_nodes=64,
                    realloc_fraction=0.02, lookahead=0.5, dist="dyadic")
    model = Phold(p)
    cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=32,
                       route_cap=256, fallback_cap=256, batch_impl="model")
    eng = ParsirEngine(model, cfg)
    st = eng.run(eng.init(), 12)
    tot = eng.totals(st)
    ref_run = run_sequential(model, 12, 0.5)
    assert tot["processed"] == ref_run.total_processed
    assert tot["late_events"] == 0 and tot["cal_overflow"] == 0
    pay = np.asarray(st.obj["payload"])
    ref_pay = np.stack([s["payload"] for s in ref_run.obj_state])
    np.testing.assert_array_equal(pay, ref_pay)
