"""Multi-device engine equivalence, run in a subprocess with 8 host devices
(device count is locked at first JAX init, so the flag must be per-process —
the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.phold.model import Phold, PholdParams
    from repro.core.engine import ParsirEngine, EngineConfig, AXIS
    from repro.core.ref_engine import run_sequential

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()), (AXIS,))
    p = PholdParams(n_objects=32, initial_events=4, state_nodes=64,
                    realloc_fraction=0.02, lookahead=0.5, dist="dyadic")
    model = Phold(p)
    n_epochs = 20
    ref = run_sequential(model, n_epochs, 0.5)
    ref_pay = np.stack([s["payload"] for s in ref.obj_state])
    ref_top = np.array([s["top"] for s in ref.obj_state])

    for route, steal in (("allgather", False), ("a2a", False),
                         ("allgather", True), ("a2a", True)):
        cfg = EngineConfig(lookahead=0.5, n_buckets=8, bucket_cap=64,
                           route_cap=512, fallback_cap=512, route=route,
                           steal=steal, steal_cap=2, claim_cap=4)
        eng = ParsirEngine(model, cfg, mesh=mesh)
        st = eng.run(eng.init(), n_epochs)
        tot = eng.totals(st)
        assert tot["processed"] == ref.total_processed, (route, steal, tot)
        assert tot["cal_overflow"] == 0 and tot["late_events"] == 0
        assert tot["route_overflow"] == 0 and tot["lookahead_violations"] == 0
        assert np.array_equal(np.asarray(st.obj["payload"]), ref_pay)
        assert np.array_equal(np.asarray(st.obj["top"]), ref_top)
        if steal:
            assert tot["stolen"] > 0, "stealing never engaged"
        print("OK", route, steal, tot["processed"], tot["stolen"])
    print("PASS")
""")


@pytest.mark.slow
def test_eight_device_engine_equivalence():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "PASS" in r.stdout
