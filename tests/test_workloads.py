"""The workload zoo × engine-config sweep (testing/conformance.py).

Every registered workload must reproduce the sequential oracle — clean
counters, equal processed count, identical pending-event multiset, bit-exact
dyadic state — under every engine configuration: both schedulers, the
batch_impl axis (dense rounds / width-packed tiles / Pallas kernel), both
routing strategies, stealing on/off, and a fractional epoch length.
Single-device sweeps run in-process; the configs that only exist with D > 1
(real a2a exchange, work stealing) run through the harness's subprocess
driver with 4 host devices.

Also here: direct coverage for the stealing caps (steal_cap / claim_cap) and
the negative-path Stats contract — undersized capacities must *count*
overflow, never silently drop.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stealing as steal_mod
from repro.core.engine import EngineConfig, ParsirEngine
from repro.testing import conformance as cf
from repro.workloads.registry import (all_workloads, conformance_spec,
                                      get_workload)

_REF_CACHE = {}

SINGLE_DEVICE_CONFIGS = ["batch-allgather", "batch-a2a", "ltf",
                         "epoch-fraction", "batch-packed",
                         # speculation (PR 9): at D=1 no straggler exists, so
                         # windows always commit — the pure leap must still be
                         # bit-exact at every width and composed with packing.
                         # spec-inject (PR 10) forces every 2nd window down
                         # the rollback path even at D=1, so the shadow
                         # restore is oracle-checked across the whole zoo.
                         "spec-w1", "spec-w4", "spec-packed-a2a",
                         "spec-inject"]
# configs that only do real work with D > 1 (pairwise a2a exchange, loans);
# the packed scheduler rides along so tiling is exercised under real
# exchange and under loan-augmented batches.  spec-a2a puts speculative
# windows under real cross-device traffic — commits AND rollbacks both land
# here (tests/test_speculation.py asserts the rollbacks actually fire) —
# and spec-steal (PR 10) composes loans with the window under the global
# all-or-nothing vote, the only verdict mode sound for borrowed batches.
MULTI_DEVICE_CONFIGS = ("batch-a2a,steal-allgather,steal-a2a,"
                        "packed-a2a,steal-packed,spec-a2a,spec-w2,"
                        "spec-steal")
# the placement sweep axis (PR 3): equal vs weighted vs adaptive must reach
# the identical drained state; exercised on the uniform, skewed and open
# topologies, with and without stealing on top.  packed-adaptive (PR 4) is
# the point of the width-packer: uneven adaptive packing without paying the
# padded-grid schedule — still the same bits.  epidemic and wireless (PR 5)
# are the state-dependent-arity and natively-hotspot loads the adaptive +
# packed machinery was built for.
PLACEMENT_WORKLOADS = ["phold", "phold-hotspot", "open-queueing",
                       "epidemic", "wireless"]
PLACEMENT_CONFIGS = "weighted,adaptive,adaptive-a2a,steal-adaptive," \
                    "packed-adaptive"


@pytest.mark.parametrize("workload", all_workloads())
@pytest.mark.parametrize("config", SINGLE_DEVICE_CONFIGS)
def test_conformance_single_device(workload, config):
    report = cf.check_workload(workload, config, ref_cache=_REF_CACHE)
    assert report["totals"]["processed"] > 0


@pytest.mark.parametrize("workload", PLACEMENT_WORKLOADS)
@pytest.mark.parametrize("config", ["weighted", "adaptive",
                                    "packed-adaptive"])
def test_conformance_placement_single_device(workload, config):
    report = cf.check_workload(workload, config, ref_cache=_REF_CACHE)
    assert report["totals"]["processed"] > 0
    if config.endswith("adaptive"):
        # the stage must actually fire (>= 2: n_epochs=24, rebalance_every=8)
        assert report["totals"]["rebalances"] >= 2


@pytest.mark.parametrize("workload",
                         [w for w in all_workloads()
                          if conformance_spec(w)["supports_batch_impl"]])
def test_conformance_batch_model_impl(workload):
    # batch_impl='model': the whole per-object batch through the Pallas
    # event-apply kernel instead of the vmap rounds loop.
    report = cf.check_workload(workload, "batch-model", ref_cache=_REF_CACHE)
    assert report["totals"]["processed"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("workload", PLACEMENT_WORKLOADS)
def test_conformance_placement_multidevice(workload):
    # 4 devices: uneven weighted ranges (padded rows), live rebalancing with
    # real row migration, and rebalancing composed with loans — all bit-exact
    # against the same oracle, firing at least twice (n_epochs=24, every 8).
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.testing.conformance",
           "--workload", workload, "--devices", "4",
           "--configs", PLACEMENT_CONFIGS, "--expect-rebalances", "2"]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "CONFORMANCE PASS" in r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("workload", all_workloads())
def test_conformance_multidevice(workload):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.testing.conformance",
           "--workload", workload, "--devices", "4",
           "--configs", MULTI_DEVICE_CONFIGS]
    if workload == "phold-hotspot":
        # the hot-spot workload exists to make loans matter: stealing MUST
        # engage on it (stats.stolen > 0) or load balancing is dead code.
        cmd.append("--expect-stolen")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "CONFORMANCE PASS" in r.stdout


# ---------------------------------------------------------------------------
# stealing caps (core/stealing.py)
# ---------------------------------------------------------------------------

def test_select_loans_respects_steal_cap():
    cnt_b = jnp.asarray([50, 40, 30, 20, 10, 5, 0, 0], jnp.int32)
    load, target = jnp.int32(155), jnp.int32(20)
    idx, w, valid = steal_mod.select_loans(cnt_b, load, target, 3)
    assert idx.shape == (3,) and w.shape == (3,) and valid.shape == (3,)
    assert int(valid.sum()) <= 3
    # published loans are the donor's hottest objects, weights match counts
    assert set(np.asarray(idx).tolist()) == {0, 1, 2}
    np.testing.assert_array_equal(np.asarray(w),
                                  np.where(np.asarray(valid),
                                           [50, 40, 30], 0))


def test_select_loans_stops_at_surplus():
    # donor barely above target: only loans that fit the surplus are valid.
    cnt_b = jnp.asarray([30, 30, 30, 30], jnp.int32)
    idx, w, valid = steal_mod.select_loans(cnt_b, jnp.int32(120),
                                           jnp.int32(100), 4)
    shipped = np.cumsum(np.asarray(w)) - np.asarray(w)
    assert np.all(shipped[np.asarray(valid)] < 20)
    assert int(np.asarray(valid).sum()) == 1  # 2nd loan would ship 30 >= 20


def test_select_loans_no_surplus_publishes_nothing():
    cnt_b = jnp.asarray([10, 10], jnp.int32)
    _, w, valid = steal_mod.select_loans(cnt_b, jnp.int32(20), jnp.int32(25), 2)
    assert int(np.asarray(valid).sum()) == 0
    assert int(np.asarray(w).sum()) == 0


def test_plan_loans_respects_claim_cap():
    D, steal_cap, claim_cap = 4, 8, 2
    loads = jnp.asarray([120, 0, 0, 0], jnp.int32)
    weight = jnp.zeros((D, steal_cap), jnp.int32).at[0].set(5)
    valid = jnp.zeros((D, steal_cap), bool).at[0].set(True)
    plan = steal_mod.plan_loans(loads, weight, valid, claim_cap)
    assignee = np.asarray(plan.assignee)
    claimed = np.asarray(plan.claimed)
    for d in range(D):
        assert claimed[assignee == d].sum() <= claim_cap
    # the overloaded donor never claims its own loans
    assert not np.any(claimed & (assignee == 0))
    assert claimed.sum() > 0


def test_hotspot_stealing_engages_multidevice():
    # satellite contract: a nonzero `stolen` counter is actually observed on
    # the hot-spot workload (the in-process single-device runs never steal).
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.testing.conformance",
         "--workload", "phold-hotspot", "--devices", "4",
         "--configs", "steal-a2a", "--expect-stolen"],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "CONFORMANCE PASS" in r.stdout


# ---------------------------------------------------------------------------
# overflow accounting: the negative path of the Stats contract
# ---------------------------------------------------------------------------

def _overflow_run(n_epochs=8, **cfg_kw):
    model = get_workload("phold", n_objects=16, initial_events=8,
                         state_nodes=64, realloc_fraction=0.02,
                         lookahead=0.5, dist="dyadic")
    defaults = dict(lookahead=0.5, n_buckets=8, bucket_cap=64,
                    route_cap=512, fallback_cap=512)
    defaults.update(cfg_kw)
    eng = ParsirEngine(model, EngineConfig(**defaults))
    st = eng.run(eng.init(), n_epochs)
    return eng.totals(st)


def test_undersized_bucket_cap_reports_cal_overflow():
    tot = _overflow_run(bucket_cap=2)
    assert tot["cal_overflow"] > 0


def test_undersized_route_cap_reports_route_overflow():
    tot = _overflow_run(route_cap=4, fallback_cap=4096)
    assert tot["route_overflow"] > 0


def test_undersized_fallback_cap_reports_fb_overflow():
    tot = _overflow_run(route_cap=4, fallback_cap=4)
    assert tot["fb_overflow"] > 0


def test_proper_caps_stay_clean():
    tot = _overflow_run()
    for counter in ("cal_overflow", "fb_overflow", "route_overflow",
                    "late_events", "lookahead_violations"):
        assert tot[counter] == 0, (counter, tot)
