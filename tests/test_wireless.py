"""Wireless workload semantics the conformance sweep can't see.

The full oracle-differential sweep lives in test_workloads.py; this file
covers the model's negative paths directly:

* the **blocked-call absorption ledger** — a cell's counters partition its
  processed events exactly (`count = arrivals + handoffs_in + dropped`,
  `arrivals = calls + blocked`), blocking really occurs under scarce
  channels, and a blocked/dropped call emits no lifecycle event;
* the **occupancy vector** admits onto the lowest-indexed free channel and
  a full vector rejects;
* a **budget-exhausted generator drains** the cell network to empty
  (`max_calls`, with handoffs disabled).

(Handoff routing's ring-neighbor edge wrap is covered once, in
test_epidemic.py — both workloads share `repro.core.events.ring_neighbor`.)
"""
import numpy as np

from repro.core import EngineConfig, ParsirEngine
from repro.core.ref_engine import run_sequential
from repro.workloads.registry import get_workload
from repro.workloads.wireless import ARRIVAL, HANDOFF

SCARCE_KW = dict(n_cells=8, n_channels=1, hot_cells=4, hot_shift=3,
                 hot_streams=3, handoff_p=128, lookahead=0.5, dist="dyadic")


def _engine(model, **cfg_kw):
    kw = dict(lookahead=model.params.lookahead, n_buckets=8, bucket_cap=64,
              route_cap=512, fallback_cap=512)
    kw.update(cfg_kw)
    return ParsirEngine(model, EngineConfig(**kw))


def _cell(model, busy_until=None):
    st = model.init_object_state_np(np.arange(model.n_objects))[0]
    if busy_until is not None:
        st["free_at"][:] = np.float32(busy_until)
    return st


def test_blocked_arrival_absorbs_call_but_keeps_generator():
    model = get_workload("wireless", **SCARCE_KW)
    st = _cell(model, busy_until=100.0)            # every channel busy
    out = model.process_event_np(st, np.float32(1.0), np.uint32(7),
                                 np.float32(ARRIVAL))
    assert int(st["blocked"]) == 1 and int(st["calls"]) == 0
    # only the generator self-loop survives — the call itself is absorbed.
    assert len(out) == 1 and float(out[0]["payload"]) == ARRIVAL
    np.testing.assert_array_equal(st["free_at"], np.float32(100.0))


def test_blocked_handoff_is_dropped_and_emits_nothing():
    model = get_workload("wireless", **SCARCE_KW)
    st = _cell(model, busy_until=100.0)
    out = model.process_event_np(st, np.float32(1.0), np.uint32(7),
                                 np.float32(HANDOFF))
    assert out == []                               # full absorption
    assert int(st["dropped"]) == 1 and int(st["handoffs_in"]) == 0


def test_admission_takes_lowest_indexed_free_channel():
    model = get_workload("wireless", n_cells=4, n_channels=4, lookahead=0.5,
                         dist="dyadic")
    st = _cell(model)
    st["free_at"][:] = np.float32([5.0, 0.25, 9.0, 0.125])  # 1 and 3 free
    model.process_event_np(st, np.float32(1.0), np.uint32(7),
                           np.float32(ARRIVAL))
    assert int(st["calls"]) == 1
    assert st["free_at"][1] >= np.float32(1.5)     # channel 1 got the call
    assert st["free_at"][3] == np.float32(0.125)   # channel 3 untouched


def test_blocked_ledger_partitions_processed_events():
    # 1 channel vs a hot arrival field: blocking must actually happen, and
    # every processed event lands in exactly one ledger bucket.
    model = get_workload("wireless", **SCARCE_KW)
    eng = _engine(model)
    st = eng.run(eng.init(), 24)
    tot = eng.totals(st)
    for counter in ("cal_overflow", "fb_overflow", "route_overflow",
                    "late_events", "lookahead_violations"):
        assert tot[counter] == 0, (counter, tot)
    obj = {k: np.asarray(v) for k, v in st.obj.items()}
    assert obj["blocked"].sum() > 0                # scarcity really binds
    assert obj["dropped"].sum() > 0                # handoffs get dropped too
    np.testing.assert_array_equal(obj["arrivals"],
                                  obj["calls"] + obj["blocked"])
    np.testing.assert_array_equal(
        obj["count"],
        obj["arrivals"] + obj["handoffs_in"] + obj["dropped"])
    # ledger agrees with the oracle bit-for-bit (dyadic occupancy vector too).
    ref = run_sequential(model, 24, eng.cfg.epoch_len)
    for k in ref.obj_state[0]:
        want = np.stack([np.asarray(s[k]) for s in ref.obj_state])
        np.testing.assert_array_equal(obj[k], want, err_msg=f"state [{k}]")


def test_exhausted_generators_drain_the_network():
    # finite per-cell arrival budget, no handoffs: after every generator
    # fires max_calls times nothing re-emits and the network empties.
    model = get_workload("wireless", n_cells=6, n_channels=2, max_calls=3,
                         handoff_p=0, lookahead=0.5, dist="dyadic")
    eng = _engine(model)
    st = eng.run_until_drained(eng.init(), 96)
    tot = eng.totals(st)
    assert eng.in_flight(st) == 0
    assert int(np.asarray(st.epoch)[0]) < 96  # drain predicate fired, not bound
    obj = {k: np.asarray(v) for k, v in st.obj.items()}
    np.testing.assert_array_equal(obj["arrivals"], np.full(6, 3))
    np.testing.assert_array_equal(obj["calls"] + obj["blocked"],
                                  obj["arrivals"])
    assert tot["processed"] == 6 * 3
