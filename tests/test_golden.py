"""Golden-digest regression suite (tier-1): the oracle vs frozen history.

The conformance sweep proves engine == oracle; these tests prove the oracle
itself hasn't drifted from the digests pinned in
``src/repro/testing/golden_digests.json``.  A failure here means the event
tree of a workload changed — RNG, model arithmetic, or oracle processing
order.  If that is intentional, regenerate deliberately::

    PYTHONPATH=src python -m repro.testing.golden --regen

and review the JSON diff like any breaking change.
"""
import pytest

from repro.testing import golden
from repro.workloads.registry import all_workloads

CASES = list(golden.golden_cases())


@pytest.mark.parametrize(
    "name,size,model_kw,n_epochs",
    CASES, ids=[f"{n}-{s}" for n, s, _, _ in CASES])
def test_golden_digest_matches_pinned(name, size, model_kw, n_epochs):
    pinned = golden.load_digests()
    key = f"{name}/{size}"
    assert key in pinned, \
        f"{key} not pinned — run `python -m repro.testing.golden --regen`"
    got = golden.compute_digest(name, model_kw, n_epochs)
    assert got == pinned[key], (
        f"{key}: oracle final-state digest drifted from frozen history "
        f"({pinned[key][:16]}… → {got[:16]}…). The workload's event tree "
        "changed; if intentional, regen golden_digests.json and review the "
        "diff.")


def test_every_workload_pinned_at_two_sizes():
    # golden coverage is part of the registry contract: each workload pins
    # exactly {small, medium}, and the JSON holds no stale keys.
    pinned = golden.load_digests()
    want = {f"{n}/{s}" for n, s, _, _ in CASES}
    assert want == set(pinned), (
        f"pinned keys diverge from registry cases: missing="
        f"{sorted(want - set(pinned))} stale={sorted(set(pinned) - want)}")
    for name in all_workloads():
        assert {f"{name}/small", f"{name}/medium"} <= set(pinned), name


def test_golden_cases_are_dyadic():
    # digests are only platform-stable on the dyadic grid — a golden case
    # accidentally running an inexact distribution would pin flaky bytes.
    for name, size, model_kw, _ in CASES:
        assert model_kw.get("dist") == "dyadic", (name, size, model_kw)
