"""Serving layer: cache shardings helper + ServeSession end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.synthetic import make_batch
from repro.models.registry import build_model
from repro.serve.engine import ServeSession, cache_shardings


def test_serve_session_greedy_decode_is_deterministic():
    cfg = get_config("granite-3-2b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 16)

    s1 = ServeSession(model, params, 2, max_len=32, dtype=np.float32)
    f1 = s1.prefill(batch)
    o1 = s1.decode(f1, 6)

    s2 = ServeSession(model, params, 2, max_len=32, dtype=np.float32)
    f2 = s2.prefill(batch)
    o2 = s2.decode(f2, 6)
    np.testing.assert_array_equal(o1, o2)
    assert o1.shape == (2, 6)


def test_cache_shardings_pick_batch_and_model_dims():
    # production-mesh geometry without devices (AbstractMesh)
    from jax.sharding import AbstractMesh
    try:
        mesh = AbstractMesh((16, 16), ("data", "model"))
    except TypeError:  # jax <= 0.4.x: shape_tuple of (name, size) pairs
        mesh = AbstractMesh((("data", 16), ("model", 16)))
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 4, 64), jnp.bfloat16),
             "h": jax.ShapeDtypeStruct((32, 16, 64), jnp.float32)}
    sh = cache_shardings(cache, mesh, batch_size=32)

    def norm(e):
        return tuple(e) if isinstance(e, tuple) else (e,)

    # batch dim (size 32, divisible by data=16) shards over data
    assert norm(sh["k"].spec[0]) == ("data",)
    assert norm(sh["h"].spec[0]) == ("data",)
    # the largest divisible non-batch dim (seq=128) gets "model"
    assert sh["k"].spec[1] == "model"
    # h: largest divisible dim is 64 (dim 2); 16 would also divide
    assert sh["h"].spec[2] == "model"
