import os
import sys

# Tests must see exactly 1 device (the dry-run sets its own 512-device flag in
# its own process); never set XLA_FLAGS globally here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
