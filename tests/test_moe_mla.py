"""MoE dispatch and MLA attention correctness tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.moe import init_mla, init_moe, mla_attention, moe_ffn


def _moe_cfg(**kw):
    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _dense_moe_reference(cfg, p, x):
    """Dense (no-capacity) MoE reference: every token to its true top-k."""
    B, T, d = x.shape
    xf = x.reshape(-1, d).astype(jnp.float32)
    logits = xf @ p["router"].astype(jnp.float32)
    gate, idx = jax.lax.top_k(logits, cfg.experts_per_token)
    gate = jax.nn.softmax(gate, axis=-1)
    y = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        w = jnp.where(idx == e, gate, 0.0).sum(axis=-1)[:, None]  # [Tt,1]
        h = jax.nn.silu(xf @ p["wg"][e].astype(jnp.float32)) * (
            xf @ p["wu"][e].astype(jnp.float32))
        y = y + w * (h @ p["wd"][e].astype(jnp.float32))
    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(xf @ sp["wg"].astype(jnp.float32)) * (
            xf @ sp["wu"].astype(jnp.float32))
        y = y + hs @ sp["wd"].astype(jnp.float32)
    return y.reshape(B, T, d)


def test_moe_dispatch_matches_dense_reference_when_capacity_suffices():
    cfg = _moe_cfg(capacity_factor=8.0)  # no drops possible
    key = jax.random.key(0)
    p = init_moe(cfg, key)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    got = moe_ffn(cfg, p, x)
    want = _dense_moe_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_moe_capacity_drops_are_bounded_not_catastrophic():
    # tiny capacity: output must stay finite and shared experts still apply.
    cfg = _moe_cfg(capacity_factor=0.01)
    p = init_moe(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y = moe_ffn(cfg, p, x)
    assert np.all(np.isfinite(np.asarray(y)))


def test_mla_absorbed_decode_equals_expanded_prefill():
    """The latent-space (absorbed) decode path must produce the same attention
    output as the expanded prefill path, position by position."""
    cfg = _moe_cfg()
    p = init_mla(cfg, jax.random.key(2))
    B, T = 1, 10
    x = jax.random.normal(jax.random.key(3), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    positions = jnp.arange(T, dtype=jnp.int32)[None]

    # expanded (training/prefill) path — full teacher-forced output
    full, _ = mla_attention(cfg, p, x, positions, cache=None)

    # absorbed decode path, one token at a time
    cache = {"ckv": jnp.zeros((B, T, cfg.kv_lora_rank), jnp.float32),
             "kr": jnp.zeros((B, T, cfg.rope_head_dim), jnp.float32)}
    outs = []
    for i in range(T):
        pos = jnp.asarray([[i]], jnp.int32)
        o, cache = mla_attention(cfg, p, x[:, i:i + 1], pos, cache=cache,
                                 cur_len=jnp.int32(i))
        outs.append(np.asarray(o[:, 0]))
    step = np.stack(outs, axis=1)
    np.testing.assert_allclose(step, np.asarray(full), atol=2e-3, rtol=2e-3)


def test_moe_conserves_tokens_under_permutation():
    """Permuting token order permutes outputs identically (no cross-token
    leakage through the dispatch buffers)."""
    cfg = _moe_cfg(capacity_factor=8.0)
    p = init_moe(cfg, jax.random.key(4))
    x = jax.random.normal(jax.random.key(5), (1, 12, cfg.d_model),
                          jnp.float32)
    perm = jnp.asarray(np.random.default_rng(0).permutation(12))
    y1 = moe_ffn(cfg, p, x)[:, perm]
    y2 = moe_ffn(cfg, p, x[:, perm])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
